//! Compressed sparse column matrix — the workhorse format of the crate.
//!
//! Invariants (checked by [`Csc::from_raw_parts`]):
//! - `colptr.len() == ncols + 1`, `colptr[0] == 0`, non-decreasing;
//! - `rowidx`/`values` have length `colptr[ncols]`;
//! - row indices within each column are strictly increasing (sorted, unique).

use super::coo::Coo;
use super::csr::Csr;

/// A compressed sparse column matrix with `f64` values.
#[derive(Debug, Clone, PartialEq)]
pub struct Csc {
    nrows: usize,
    ncols: usize,
    colptr: Vec<usize>,
    rowidx: Vec<usize>,
    values: Vec<f64>,
}

impl Csc {
    /// Build from raw CSC arrays, validating all invariants.
    pub fn from_raw_parts(
        nrows: usize,
        ncols: usize,
        colptr: Vec<usize>,
        rowidx: Vec<usize>,
        values: Vec<f64>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(colptr.len() == ncols + 1, "colptr length mismatch");
        anyhow::ensure!(colptr[0] == 0, "colptr[0] != 0");
        anyhow::ensure!(
            rowidx.len() == *colptr.last().unwrap() && values.len() == rowidx.len(),
            "index/value array length mismatch"
        );
        for c in 0..ncols {
            anyhow::ensure!(colptr[c] <= colptr[c + 1], "colptr not monotone at {c}");
            let col = &rowidx[colptr[c]..colptr[c + 1]];
            for w in col.windows(2) {
                anyhow::ensure!(w[0] < w[1], "rows not strictly increasing in col {c}");
            }
            if let Some(&last) = col.last() {
                anyhow::ensure!(last < nrows, "row index out of range in col {c}");
            }
        }
        Ok(Csc {
            nrows,
            ncols,
            colptr,
            rowidx,
            values,
        })
    }

    /// Identity matrix of size `n`.
    pub fn identity(n: usize) -> Self {
        Csc {
            nrows: n,
            ncols: n,
            colptr: (0..=n).collect(),
            rowidx: (0..n).collect(),
            values: vec![1.0; n],
        }
    }

    /// Build from a dense row-major matrix, keeping entries with `|v| > 0`.
    pub fn from_dense(nrows: usize, ncols: usize, dense: &[f64]) -> Self {
        assert_eq!(dense.len(), nrows * ncols);
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                let v = dense[r * ncols + c];
                if v != 0.0 {
                    coo.push(r, c, v);
                }
            }
        }
        coo.to_csc()
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.rowidx.len()
    }

    pub fn colptr(&self) -> &[usize] {
        &self.colptr
    }

    pub fn rowidx(&self) -> &[usize] {
        &self.rowidx
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn values_mut(&mut self) -> &mut [f64] {
        &mut self.values
    }

    /// Split borrow: `(colptr, rowidx, values_mut)` — lets numeric kernels
    /// walk the immutable pattern while scattering into the values without
    /// per-column copies (the factorization hot path).
    pub fn split_mut(&mut self) -> (&[usize], &[usize], &mut [f64]) {
        (&self.colptr, &self.rowidx, &mut self.values)
    }

    /// The `(rows, values)` slices of column `c`.
    #[inline]
    pub fn col(&self, c: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.colptr[c], self.colptr[c + 1]);
        (&self.rowidx[s..e], &self.values[s..e])
    }

    /// Value at `(r, c)`; 0.0 if not stored. O(log nnz(col)).
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (rows, vals) = self.col(c);
        match rows.binary_search(&r) {
            Ok(i) => vals[i],
            Err(_) => 0.0,
        }
    }

    /// Whether `(r, c)` is a stored (structural) entry.
    pub fn has_entry(&self, r: usize, c: usize) -> bool {
        self.col(c).0.binary_search(&r).is_ok()
    }

    /// Position of `(r, c)` in the value array, if stored.
    pub fn entry_index(&self, r: usize, c: usize) -> Option<usize> {
        let (rows, _) = self.col(c);
        rows.binary_search(&r).ok().map(|i| self.colptr[c] + i)
    }

    /// `y = A * x`.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for c in 0..self.ncols {
            let xc = x[c];
            if xc == 0.0 {
                continue;
            }
            let (rows, vals) = self.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                y[r] += v * xc;
            }
        }
        y
    }

    /// Transpose (also the CSC<->CSR pivot).
    pub fn transpose(&self) -> Csc {
        let mut rowcount = vec![0usize; self.nrows + 1];
        for &r in &self.rowidx {
            rowcount[r + 1] += 1;
        }
        for r in 0..self.nrows {
            rowcount[r + 1] += rowcount[r];
        }
        let mut colptr = rowcount.clone();
        let mut rowidx = vec![0usize; self.nnz()];
        let mut values = vec![0f64; self.nnz()];
        let mut next = rowcount;
        for c in 0..self.ncols {
            let (rows, vals) = self.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                let p = next[r];
                rowidx[p] = c;
                values[p] = v;
                next[r] += 1;
            }
        }
        colptr.rotate_right(0); // already cumulative
        Csc {
            nrows: self.ncols,
            ncols: self.nrows,
            colptr,
            rowidx,
            values,
        }
    }

    /// Same pattern+values viewed as CSR (row-compressed).
    pub fn to_csr(&self) -> Csr {
        let t = self.transpose();
        // CSR of A == CSC of A^T with rows/cols swapped.
        Csr::from_raw_parts(self.nrows, self.ncols, t.colptr, t.rowidx, t.values)
            .expect("transpose produced invalid CSR")
    }

    /// Dense row-major copy (test/debug helper; asserts small sizes).
    pub fn to_dense(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.nrows * self.ncols];
        for c in 0..self.ncols {
            let (rows, vals) = self.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                d[r * self.ncols + c] = v;
            }
        }
        d
    }

    /// Symmetric permutation+ scaling `P R A C Q` where `perm_row` maps
    /// old row -> new row and `perm_col` maps old col -> new col; `r_scale`
    /// and `c_scale` are optional diagonal scalings applied as
    /// `A'(pr[i], pc[j]) = r[i] * A(i,j) * c[j]`.
    pub fn permute_scale(
        &self,
        perm_row: &[usize],
        perm_col: &[usize],
        r_scale: Option<&[f64]>,
        c_scale: Option<&[f64]>,
    ) -> Csc {
        assert_eq!(perm_row.len(), self.nrows);
        assert_eq!(perm_col.len(), self.ncols);
        let mut coo = Coo::new(self.nrows, self.ncols);
        for c in 0..self.ncols {
            let (rows, vals) = self.col(c);
            for (&r, &v) in rows.iter().zip(vals) {
                let mut w = v;
                if let Some(rs) = r_scale {
                    w *= rs[r];
                }
                if let Some(cs) = c_scale {
                    w *= cs[c];
                }
                coo.push(perm_row[r], perm_col[c], w);
            }
        }
        coo.to_csc()
    }

    /// Convenience: `A(P,Q)` permutation without scaling.
    pub fn permute(&self, perm_row: &[usize], perm_col: &[usize]) -> Csc {
        self.permute_scale(perm_row, perm_col, None, None)
    }

    /// Structural pattern of `A + A^T` (values summed; used by AMD which
    /// wants a symmetric pattern).
    pub fn plus_transpose_pattern(&self) -> Csc {
        assert_eq!(self.nrows, self.ncols);
        let t = self.transpose();
        let mut coo = Coo::new(self.nrows, self.ncols);
        for c in 0..self.ncols {
            let (rows, _) = self.col(c);
            for &r in rows {
                coo.push(r, c, 1.0);
            }
            let (rows, _) = t.col(c);
            for &r in rows {
                coo.push(r, c, 1.0);
            }
        }
        coo.to_csc()
    }

    /// Whether every diagonal entry is structurally present (required before
    /// factorization; MC64 matching establishes it).
    pub fn has_full_diagonal(&self) -> bool {
        assert_eq!(self.nrows, self.ncols);
        (0..self.ncols).all(|j| self.has_entry(j, j))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.values.iter().map(|v| v * v).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Csc {
        // [1 0 2]
        // [0 3 0]
        // [4 0 5]
        Csc::from_dense(3, 3, &[1.0, 0.0, 2.0, 0.0, 3.0, 0.0, 4.0, 0.0, 5.0])
    }

    #[test]
    fn from_raw_parts_validates() {
        assert!(Csc::from_raw_parts(2, 2, vec![0, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_ok());
        // bad colptr head
        assert!(Csc::from_raw_parts(2, 2, vec![1, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_err());
        // unsorted rows
        assert!(Csc::from_raw_parts(3, 1, vec![0, 2], vec![2, 1], vec![1.0, 2.0]).is_err());
        // duplicate rows
        assert!(Csc::from_raw_parts(3, 1, vec![0, 2], vec![1, 1], vec![1.0, 2.0]).is_err());
        // out-of-range row
        assert!(Csc::from_raw_parts(2, 1, vec![0, 1], vec![5], vec![1.0]).is_err());
    }

    #[test]
    fn get_and_nnz() {
        let a = small();
        assert_eq!(a.nnz(), 5);
        assert_eq!(a.get(0, 0), 1.0);
        assert_eq!(a.get(0, 2), 2.0);
        assert_eq!(a.get(2, 2), 5.0);
        assert_eq!(a.get(1, 0), 0.0);
    }

    #[test]
    fn matvec_matches_dense() {
        let a = small();
        let y = a.matvec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1.0 + 6.0, 6.0, 4.0 + 15.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = small();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
        assert_eq!(a.transpose().get(2, 0), 2.0);
    }

    #[test]
    fn to_csr_matches() {
        let a = small();
        let csr = a.to_csr();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(a.get(r, c), csr.get(r, c), "({r},{c})");
            }
        }
    }

    #[test]
    fn permute_identity_is_noop() {
        let a = small();
        let id: Vec<usize> = (0..3).collect();
        assert_eq!(a.permute(&id, &id), a);
    }

    #[test]
    fn permute_swap_rows() {
        let a = small();
        // swap rows 0 and 2
        let p = vec![2, 1, 0];
        let id: Vec<usize> = (0..3).collect();
        let b = a.permute(&p, &id);
        assert_eq!(b.get(0, 0), 4.0);
        assert_eq!(b.get(2, 0), 1.0);
    }

    #[test]
    fn scaling_applied() {
        let a = small();
        let id: Vec<usize> = (0..3).collect();
        let b = a.permute_scale(&id, &id, Some(&[2.0, 1.0, 1.0]), Some(&[1.0, 1.0, 10.0]));
        assert_eq!(b.get(0, 0), 2.0);
        assert_eq!(b.get(0, 2), 40.0); // 2 * 2 * 10
        assert_eq!(b.get(2, 2), 50.0);
    }

    #[test]
    fn plus_transpose_symmetric() {
        let a = small();
        let s = a.plus_transpose_pattern();
        for r in 0..3 {
            for c in 0..3 {
                assert_eq!(s.has_entry(r, c), s.has_entry(c, r));
            }
        }
        assert!(s.has_entry(0, 2) && s.has_entry(2, 0));
    }

    #[test]
    fn full_diagonal_check() {
        assert!(small().has_full_diagonal());
        let b = Csc::from_dense(2, 2, &[0.0, 1.0, 1.0, 0.0]);
        assert!(!b.has_full_diagonal());
    }

    #[test]
    fn identity_properties() {
        let i = Csc::identity(4);
        assert_eq!(i.nnz(), 4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(i.matvec(&x), x);
    }
}
