//! Matrix Market (`.mtx`) reader / writer.
//!
//! The paper's suite comes from the UFL (SuiteSparse) collection, distributed
//! in Matrix Market format. This environment is offline, so benchmarks run on
//! the synthetic suite from [`crate::sparse::gen`] by default — but any real
//! UFL `.mtx` file dropped next to the binary loads through this module
//! unchanged (`coordinate real/integer/pattern`, `general/symmetric`).

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context};

use super::coo::Coo;
use super::csc::Csc;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Field {
    Real,
    Integer,
    Pattern,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Read a Matrix Market coordinate file into CSC.
pub fn read_matrix_market(path: impl AsRef<Path>) -> anyhow::Result<Csc> {
    let file = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    read_matrix_market_from(std::io::BufReader::new(file))
}

/// Read Matrix Market from any buffered reader (exposed for tests).
pub fn read_matrix_market_from(reader: impl BufRead) -> anyhow::Result<Csc> {
    let mut lines = reader.lines();

    // Header: %%MatrixMarket matrix coordinate <field> <symmetry>
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                if !l.trim().is_empty() {
                    break l;
                }
            }
            None => bail!("empty file"),
        }
    };
    let toks: Vec<String> = header
        .trim()
        .split_whitespace()
        .map(|t| t.to_ascii_lowercase())
        .collect();
    if toks.len() < 5 || toks[0] != "%%matrixmarket" || toks[1] != "matrix" {
        bail!("not a MatrixMarket matrix header: {header}");
    }
    if toks[2] != "coordinate" {
        bail!("only coordinate format supported, got {}", toks[2]);
    }
    let field = match toks[3].as_str() {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        other => bail!("unsupported field type {other}"),
    };
    let symmetry = match toks[4].as_str() {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        other => bail!("unsupported symmetry {other}"),
    };

    // Size line (after comments).
    let size_line = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                let t = l.trim();
                if !t.is_empty() && !t.starts_with('%') {
                    break l;
                }
            }
            None => bail!("missing size line"),
        }
    };
    let mut it = size_line.trim().split_whitespace();
    let nrows: usize = it.next().context("missing nrows")?.parse()?;
    let ncols: usize = it.next().context("missing ncols")?.parse()?;
    let nnz: usize = it.next().context("missing nnz")?.parse()?;

    let mut coo = Coo::new(nrows, ncols);
    let mut read = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut f = t.split_whitespace();
        let r: usize = f.next().context("missing row")?.parse::<usize>()? - 1;
        let c: usize = f.next().context("missing col")?.parse::<usize>()? - 1;
        let v: f64 = match field {
            Field::Pattern => 1.0,
            _ => f.next().context("missing value")?.parse()?,
        };
        if r >= nrows || c >= ncols {
            bail!("entry ({},{}) outside {}x{}", r + 1, c + 1, nrows, ncols);
        }
        coo.push(r, c, v);
        match symmetry {
            Symmetry::General => {}
            Symmetry::Symmetric => {
                if r != c {
                    coo.push(c, r, v);
                }
            }
            Symmetry::SkewSymmetric => {
                if r != c {
                    coo.push(c, r, -v);
                }
            }
        }
        read += 1;
    }
    if read != nnz {
        bail!("expected {nnz} entries, found {read}");
    }
    Ok(coo.to_csc())
}

/// Write a CSC matrix as `coordinate real general`.
pub fn write_matrix_market(path: impl AsRef<Path>, a: &Csc) -> anyhow::Result<()> {
    let file = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {}", path.as_ref().display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by glu3 (GLU3.0 reproduction)")?;
    writeln!(w, "{} {} {}", a.nrows(), a.ncols(), a.nnz())?;
    for c in 0..a.ncols() {
        let (rows, vals) = a.col(c);
        for (&r, &v) in rows.iter().zip(vals) {
            writeln!(w, "{} {} {:.17e}", r + 1, c + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general_real() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % comment\n\
                    3 3 4\n\
                    1 1 1.5\n\
                    2 2 -2.0\n\
                    3 1 4.0\n\
                    3 3 1e2\n";
        let a = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(a.nrows(), 3);
        assert_eq!(a.nnz(), 4);
        assert_eq!(a.get(0, 0), 1.5);
        assert_eq!(a.get(2, 0), 4.0);
        assert_eq!(a.get(2, 2), 100.0);
    }

    #[test]
    fn parse_symmetric_mirrors() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    2 2 2\n\
                    1 1 2.0\n\
                    2 1 3.0\n";
        let a = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(a.nnz(), 3);
        assert_eq!(a.get(0, 1), 3.0);
        assert_eq!(a.get(1, 0), 3.0);
    }

    #[test]
    fn parse_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n\
                    2 2 2\n\
                    1 2\n\
                    2 1\n";
        let a = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(a.get(0, 1), 1.0);
        assert_eq!(a.get(1, 0), 1.0);
    }

    #[test]
    fn rejects_bad_header_and_counts() {
        assert!(read_matrix_market_from(Cursor::new("garbage\n1 1 0\n")).is_err());
        let short = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_matrix_market_from(Cursor::new(short)).is_err());
    }

    #[test]
    fn roundtrip_through_tempfile() {
        let mut coo = Coo::new(4, 4);
        coo.push(0, 0, 1.0);
        coo.push(3, 1, -2.5);
        coo.push(2, 3, 1e-8);
        let a = coo.to_csc();
        let path = std::env::temp_dir().join("glu3_io_roundtrip.mtx");
        write_matrix_market(&path, &a).unwrap();
        let b = read_matrix_market(&path).unwrap();
        assert_eq!(a, b);
        std::fs::remove_file(path).ok();
    }
}
