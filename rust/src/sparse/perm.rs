//! Permutation vectors with explicit direction.
//!
//! Ordering code is a classic source of perm/inverse-perm bugs; this type
//! pins the convention: `perm[old] == new` ("scatter" form), matching
//! [`crate::sparse::Csc::permute`].

/// A permutation of `0..n` stored in scatter form: `perm[old] = new`.
#[derive(Debug, Clone, PartialEq)]
pub struct Permutation {
    perm: Vec<usize>,
}

impl Permutation {
    /// Identity permutation of size `n`.
    pub fn identity(n: usize) -> Self {
        Permutation {
            perm: (0..n).collect(),
        }
    }

    /// From a scatter-form vector (`perm[old] = new`), validated.
    pub fn from_scatter(perm: Vec<usize>) -> anyhow::Result<Self> {
        let n = perm.len();
        let mut seen = vec![false; n];
        for &p in &perm {
            anyhow::ensure!(p < n, "permutation value {p} out of range");
            anyhow::ensure!(!seen[p], "duplicate permutation value {p}");
            seen[p] = true;
        }
        Ok(Permutation { perm })
    }

    /// From gather form (`order[new] = old`, e.g. an elimination order).
    pub fn from_order(order: &[usize]) -> anyhow::Result<Self> {
        let n = order.len();
        let mut perm = vec![usize::MAX; n];
        for (new, &old) in order.iter().enumerate() {
            anyhow::ensure!(old < n, "order value {old} out of range");
            anyhow::ensure!(perm[old] == usize::MAX, "duplicate order value {old}");
            perm[old] = new;
        }
        Ok(Permutation { perm })
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Scatter-form slice: `as_scatter()[old] = new`.
    pub fn as_scatter(&self) -> &[usize] {
        &self.perm
    }

    /// Gather form: `gather()[new] = old`.
    pub fn gather(&self) -> Vec<usize> {
        let mut inv = vec![0usize; self.perm.len()];
        for (old, &new) in self.perm.iter().enumerate() {
            inv[new] = old;
        }
        inv
    }

    /// Inverse permutation (scatter form of the inverse).
    pub fn inverse(&self) -> Permutation {
        Permutation {
            perm: self.gather(),
        }
    }

    /// Apply to a vector: `out[perm[i]] = x[i]`.
    pub fn apply<T: Clone + Default>(&self, x: &[T]) -> Vec<T> {
        assert_eq!(x.len(), self.perm.len());
        let mut out = vec![T::default(); x.len()];
        for (old, &new) in self.perm.iter().enumerate() {
            out[new] = x[old].clone();
        }
        out
    }

    /// Compose: `self` then `other` (`(other ∘ self)[old] = other[self[old]]`).
    pub fn then(&self, other: &Permutation) -> Permutation {
        assert_eq!(self.len(), other.len());
        Permutation {
            perm: self.perm.iter().map(|&m| other.perm[m]).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_apply() {
        let p = Permutation::identity(3);
        assert_eq!(p.apply(&[1, 2, 3]), vec![1, 2, 3]);
    }

    #[test]
    fn scatter_validation() {
        assert!(Permutation::from_scatter(vec![1, 1]).is_err());
        assert!(Permutation::from_scatter(vec![2, 0]).is_err());
        assert!(Permutation::from_scatter(vec![1, 0]).is_ok());
    }

    #[test]
    fn order_vs_scatter() {
        // elimination order: first eliminate old index 2, then 0, then 1.
        let p = Permutation::from_order(&[2, 0, 1]).unwrap();
        assert_eq!(p.as_scatter(), &[1, 2, 0]); // old 0 -> position 1, etc.
        assert_eq!(p.gather(), vec![2, 0, 1]);
    }

    #[test]
    fn inverse_composes_to_identity() {
        let p = Permutation::from_scatter(vec![2, 0, 3, 1]).unwrap();
        let id = p.then(&p.inverse());
        assert_eq!(id, Permutation::identity(4));
    }

    #[test]
    fn apply_scatters() {
        let p = Permutation::from_scatter(vec![2, 0, 1]).unwrap();
        assert_eq!(p.apply(&[10, 20, 30]), vec![20, 30, 10]);
    }
}
