//! Compressed sparse row matrix — used where row access dominates
//! (dependency detection "look left along row k", Matrix Market export,
//! and the GLU2.0 double-U search which walks rows).

/// A compressed sparse row matrix with `f64` values.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    nrows: usize,
    ncols: usize,
    rowptr: Vec<usize>,
    colidx: Vec<usize>,
    values: Vec<f64>,
}

impl Csr {
    /// Build from raw CSR arrays, validating invariants (mirror of CSC).
    pub fn from_raw_parts(
        nrows: usize,
        ncols: usize,
        rowptr: Vec<usize>,
        colidx: Vec<usize>,
        values: Vec<f64>,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(rowptr.len() == nrows + 1, "rowptr length mismatch");
        anyhow::ensure!(rowptr[0] == 0, "rowptr[0] != 0");
        anyhow::ensure!(
            colidx.len() == *rowptr.last().unwrap() && values.len() == colidx.len(),
            "index/value array length mismatch"
        );
        for r in 0..nrows {
            anyhow::ensure!(rowptr[r] <= rowptr[r + 1], "rowptr not monotone at {r}");
            let row = &colidx[rowptr[r]..rowptr[r + 1]];
            for w in row.windows(2) {
                anyhow::ensure!(w[0] < w[1], "cols not strictly increasing in row {r}");
            }
            if let Some(&last) = row.last() {
                anyhow::ensure!(last < ncols, "col index out of range in row {r}");
            }
        }
        Ok(Csr {
            nrows,
            ncols,
            rowptr,
            colidx,
            values,
        })
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    pub fn ncols(&self) -> usize {
        self.ncols
    }

    pub fn nnz(&self) -> usize {
        self.colidx.len()
    }

    pub fn rowptr(&self) -> &[usize] {
        &self.rowptr
    }

    pub fn colidx(&self) -> &[usize] {
        &self.colidx
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    /// The `(cols, values)` slices of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> (&[usize], &[f64]) {
        let (s, e) = (self.rowptr[r], self.rowptr[r + 1]);
        (&self.colidx[s..e], &self.values[s..e])
    }

    /// Value at `(r, c)`; 0.0 if not stored.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&c) {
            Ok(i) => vals[i],
            Err(_) => 0.0,
        }
    }

    /// Whether `(r, c)` is a stored entry.
    pub fn has_entry(&self, r: usize, c: usize) -> bool {
        self.row(r).0.binary_search(&c).is_ok()
    }

    /// `y = A * x` (row-major traversal).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0.0; self.nrows];
        for r in 0..self.nrows {
            let (cols, vals) = self.row(r);
            let mut acc = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c];
            }
            y[r] = acc;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Csc;

    #[test]
    fn validation() {
        assert!(Csr::from_raw_parts(2, 2, vec![0, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_ok());
        assert!(Csr::from_raw_parts(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 2.0]).is_err());
        assert!(Csr::from_raw_parts(1, 2, vec![0, 2], vec![1, 1], vec![1.0, 2.0]).is_err());
    }

    #[test]
    fn row_access_and_matvec() {
        let a = Csc::from_dense(2, 3, &[1.0, 0.0, 2.0, 0.0, 3.0, 4.0]).to_csr();
        assert_eq!(a.row(0).0, &[0, 2]);
        assert_eq!(a.get(1, 1), 3.0);
        assert!(!a.has_entry(1, 0));
        assert_eq!(a.matvec(&[1.0, 1.0, 1.0]), vec![3.0, 7.0]);
    }
}
