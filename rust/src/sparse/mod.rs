//! Sparse matrix substrate: COO / CSC / CSR storage, conversions,
//! permutation, Matrix Market I/O, and synthetic circuit-matrix generators.
//!
//! CSC is the primary format — every LU algorithm in this crate is
//! column-based, matching the Gilbert–Peierls tradition (KLU, NICSLU, GLU).

pub mod coo;
pub mod csc;
pub mod csr;
pub mod gen;
pub mod io;
pub mod perm;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;
pub use perm::Permutation;
