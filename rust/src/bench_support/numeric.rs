//! Wall-clock numeric bench harness → `BENCH_numeric.json`.
//!
//! Times `factor` (full pipeline), `refactor` (numeric kernel only — the
//! Newton hot path) and `solve` for every numeric engine across a set of
//! thread counts, plus the head-to-head that motivated the persistent
//! worker pool: pool-based [`parlu::factor_with`] vs the seed's
//! per-level-spawn baseline [`parlu::factor_spawn_per_level_with`] on the
//! same precomputed schedule (so the measured difference is purely worker
//! orchestration). The report also carries a `plan` block — the
//! [`crate::plan::FactorPlan`]'s per-level mode histogram plus the
//! preprocessing stage wall-clocks (symbolic / detect / levelize / plan
//! build), making the paper's detection-speedup claim directly
//! measurable per run — and, since schema v3, a `refactor_loop` block:
//! N repeated refactorizations of one fixed pattern timed per iteration,
//! the scatter-mapped indexed engine ([`parrl::refactor_in_place`])
//! head-to-head against the search-based baseline
//! ([`parrl::refactor_in_place_search`]) on the same plan and pool, plus
//! the one-time scatter build cost being amortized. Schema v5 adds a
//! `robustness` block: the numeric-repair-ladder counters (perturbations,
//! refinement steps, escalations, accepted probe residual) from one
//! deterministic singular refactor, proving the in-place repair path per
//! run. Schema v6 adds a `symbolic` block: the cold-start anatomy of the
//! once-per-pattern phase — serial fill+detect+levelize against the
//! wave-parallel discovery ([`crate::symbolic::parfill`]) per thread
//! count, and the cold pipeline against the incremental near-miss patch
//! ([`crate::symbolic::delta`]) on a one-entry structural delta of the
//! same pattern. Schema v7 adds a `rescue` block: the rung-5
//! threshold-partial-pivoting counters ([`crate::numeric::pivlu`]) from
//! one deterministic fixed-order-exhausted refactor — rescues, swapped
//! pivots, the cold rescue wall-clock beside the post-rescue fast-path
//! refactor wall-clock, and the rescued probe residual. Schema v8 adds a
//! `batched` block: the value-plane head-to-head — `B` looped refactors
//! against one [`GluSolver::refactor_batch`] schedule walk and `B`
//! single-RHS solves against one blocked [`GluSolver::solve_many_into`]
//! trisolve walk, per batch size `B ∈ {1, 4, 16}` — plus the histogram
//! of trisolve variants (sequential / level-set / sync-free) the timed
//! solvers ran. Wired into the CLI as `glu3 bench` and into CI as a
//! schema-validated smoke job; the perf trajectory lives in the emitted
//! JSON, not in a CI gate (except the two v6 symbolic floors and the v8
//! batched-refactor floor asserted by `bench_smoke`).
//!
//! All timings are medians (factor/refactor/solve) or minima (the
//! spawn-vs-pool ratio, where min is the stable statistic) over
//! `iters` runs after `warmup` discarded runs, in milliseconds.

use crate::glu::{ExecBackend, GluOptions, GluSolver, NumericEngine};
use crate::numeric::{parlu, parrl, PivotMonitor, WorkerPool};
use crate::sparse::{gen, Csc};
use crate::symbolic::{
    changed_columns, parallel_symbolic, patch_symbolic, symbolic_fill, symbolic_fill_with,
    FillWorkspace, SymbolicFill,
};
use crate::util::stats::percentile;
use crate::util::timer::measure;

/// What to bench: one matrix, several thread counts, a sampling plan.
pub struct BenchSpec {
    /// Label recorded in the JSON (e.g. `grid2d-100x100-amd`).
    pub label: String,
    /// The (unordered) input matrix; engines apply AMD internally and the
    /// spawn-vs-pool head-to-head pre-permutes with AMD explicitly.
    pub a: Csc,
    /// Thread counts for the parallel engines (sequential engines run once).
    pub thread_counts: Vec<usize>,
    /// Discarded warmup runs per measurement.
    pub warmup: usize,
    /// Recorded runs per measurement.
    pub iters: usize,
}

impl BenchSpec {
    /// Small fixture for CI smoke runs: fast, but still multi-level.
    pub fn smoke() -> Self {
        BenchSpec {
            label: "grid2d-30x30-amd".to_string(),
            a: gen::grid2d(30, 30, 7),
            thread_counts: vec![1, 2],
            warmup: 0,
            iters: 2,
        }
    }

    /// The acceptance fixture: 100×100 AMD-ordered 2-D grid, 4 threads —
    /// where pool-based `parlu` must beat the per-level-spawn baseline by
    /// ≥ 2× wall-clock.
    pub fn acceptance() -> Self {
        BenchSpec {
            label: "grid2d-100x100-amd".to_string(),
            a: gen::grid2d(100, 100, 7),
            thread_counts: vec![1, 2, 4],
            warmup: 1,
            iters: 3,
        }
    }
}

/// One engine × thread-count row of the report.
#[derive(Debug, Clone)]
pub struct EngineSample {
    pub engine: String,
    pub threads: usize,
    /// Median wall-clock of `GluSolver::factor` (full pipeline), ms.
    pub factor_ms: f64,
    /// Median wall-clock of `GluSolver::refactor` (numeric only), ms.
    pub refactor_ms: f64,
    /// Median wall-clock of one `GluSolver::solve`, ms.
    pub solve_ms: f64,
}

/// The plan block of the report: per-level kernel-mode histogram plus the
/// preprocessing stage wall-clocks of one default-policy factorization —
/// the data behind the paper's detection-speedup claim (Table II) and the
/// Table III A/B/C distribution, now measured per bench run.
#[derive(Debug, Clone)]
pub struct PlanReport {
    /// Levels in the schedule.
    pub levels: usize,
    /// Small-block (type A) levels.
    pub modes_small: usize,
    /// Large-block (type B) levels.
    pub modes_large: usize,
    /// Stream (type C) levels.
    pub modes_stream: usize,
    /// Plan build wall-clock, ms (`GluStats::plan_ms` of the profiled
    /// factorization).
    pub build_ms: f64,
    /// Total symbolic wall-clock (fill + detect + levelize), ms — matches
    /// `GluStats::symbolic_ms` since schema v6.
    pub symbolic_ms: f64,
    /// Fill discovery wall-clock, ms (v6).
    pub fillin_ms: f64,
    /// Dependency detection wall-clock, ms.
    pub detect_ms: f64,
    /// Levelization wall-clock, ms.
    pub levelize_ms: f64,
}

/// The refactor-loop head-to-head (schema v3): N repeated refactors of a
/// fixed pattern, per-iteration wall-clock, the indexed scatter-mapped
/// engine against the search-based baseline on the same plan, pool, and
/// stamped values — the measured difference is exactly the per-refactor
/// position searching and CAS traffic the [`crate::plan::ScatterMap`] and
/// destination ownership remove.
#[derive(Debug, Clone)]
pub struct RefactorLoopReport {
    /// Worker threads (the largest requested thread count).
    pub threads: usize,
    /// Recorded iterations per engine (warmup discarded).
    pub iterations: usize,
    /// One-time scatter map build, ms (the pattern-time cost amortized by
    /// the loop).
    pub scatter_build_ms: f64,
    /// Per-iteration wall-clock of the indexed engine, ms.
    pub indexed_ms: Vec<f64>,
    /// Per-iteration wall-clock of the search-based baseline, ms.
    pub search_ms: Vec<f64>,
    /// MAC commits per refactor executed as plain stores instead of CAS
    /// (the plan's ownership/chain levels).
    pub atomic_commits_avoided: u64,
}

impl RefactorLoopReport {
    /// Median indexed iteration, ms.
    pub fn indexed_median_ms(&self) -> f64 {
        percentile(&self.indexed_ms, 50.0)
    }

    /// Median search-based iteration, ms.
    pub fn search_median_ms(&self) -> f64 {
        percentile(&self.search_ms, 50.0)
    }

    /// How much the indexed path wins by (≥ 1.5 is the acceptance bar on
    /// the 100×100 AMD grid at 4 threads).
    pub fn speedup(&self) -> f64 {
        self.search_median_ms() / self.indexed_median_ms().max(1e-9)
    }
}

/// The schedule block (schema v4): the lowered [`crate::runtime::LaunchSchedule`]
/// executed through the [`crate::runtime::executor::VirtualDevice`]
/// backend, with per-level executed-vs-simulated cycle reconciliation —
/// `simulated_cycles` is the full gpusim latency model (exactly what the
/// simulated engine charges), `executed_cycles` the issue-only makespan of
/// the same launch geometry; the per-level delta is the model's
/// latency/launch-overhead prediction, recorded per bench run.
#[derive(Debug, Clone)]
pub struct ScheduleReport {
    /// Levels (one planned launch per level).
    pub levels: usize,
    /// Total kernel invocations across the schedule (tiling included).
    pub total_launches: u64,
    /// Distinct artifact names the schedule dispatches.
    pub kernels: Vec<String>,
    /// Per-level issue-only cycles.
    pub executed_cycles: Vec<u64>,
    /// Per-level full-model cycles.
    pub simulated_cycles: Vec<u64>,
}

impl ScheduleReport {
    /// Total issue-only cycles.
    pub fn executed_total(&self) -> u64 {
        self.executed_cycles.iter().sum()
    }

    /// Total full-model cycles.
    pub fn simulated_total(&self) -> u64 {
        self.simulated_cycles.iter().sum()
    }

    /// Total simulated-minus-executed delta.
    pub fn cycle_delta(&self) -> i64 {
        self.simulated_total() as i64 - self.executed_total() as i64
    }
}

/// Extract the schedule block from a factored schedule-engine solver
/// (`None` for any other engine — its stats carry no execution report).
pub fn schedule_report(solver: &GluSolver) -> Option<ScheduleReport> {
    let exec = solver.stats().exec.as_ref()?;
    Some(ScheduleReport {
        levels: exec.per_launch.len(),
        total_launches: exec.total_launches(),
        kernels: solver
            .plan()
            .launch_schedule()
            .kernels_used()
            .iter()
            .map(|s| s.to_string())
            .collect(),
        executed_cycles: exec.per_launch.iter().map(|l| l.executed_cycles).collect(),
        simulated_cycles: exec.per_launch.iter().map(|l| l.simulated_cycles).collect(),
    })
}

/// The robustness block (schema v5): the numeric-repair ladder driven
/// once per bench run on a deterministic singular refactor — healthy
/// tridiagonal pattern factored, then restamped with its first pivot
/// zeroed ([`gen::weaken_diagonal`]) so the diagonal-perturbation +
/// iterative-refinement rung must fire. The recorded counters prove,
/// per run, that a zero pivot is repaired *in place* (no symbolic
/// rerun) within the probe tolerance.
#[derive(Debug, Clone)]
pub struct RobustnessReport {
    /// Element growth proxy of the repaired run.
    pub pivot_growth: f64,
    /// Condition proxy (max/min pivot magnitude) of the repaired run.
    pub condition_estimate: f64,
    /// Diagonal-perturbation attempts the ladder spent.
    pub perturbations: u64,
    /// Iterative-refinement correction steps applied.
    pub refine_iters: u64,
    /// Escalations to a fresh re-equilibration on the fixed pattern.
    pub escalations: u64,
    /// Refactors that would have failed outright but were repaired.
    pub repairs: u64,
    /// Scaled probe residual the accepted repair achieved.
    pub probe_residual: f64,
}

/// Drive the repair ladder on the deterministic singular-refactor fixture
/// and capture the counters. Natural ordering and no scaling keep the
/// MC64 matching at identity on the diagonally dominant tridiagonal, so
/// the zeroed entry is *guaranteed* to land on a pivot.
pub fn robustness_report() -> anyhow::Result<RobustnessReport> {
    use crate::order::FillOrdering;
    use crate::sparse::Coo;

    let n = 64;
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 4.0);
        if i + 1 < n {
            coo.push(i, i + 1, -1.0);
            coo.push(i + 1, i, -1.0);
        }
    }
    let a = coo.to_csc();
    let bad = gen::weaken_diagonal(&a, n, 0.0); // A(0,0) = 0
    let opts = GluOptions {
        ordering: FillOrdering::Natural,
        scale: false,
        ..Default::default()
    };
    let mut solver = GluSolver::factor(&a, &opts)?;
    solver.refactor(&bad)?;
    let st = solver.stats();
    anyhow::ensure!(
        st.symbolic_runs == 1,
        "the repair must reuse the cached symbolic state"
    );
    let r = &st.robustness;
    Ok(RobustnessReport {
        pivot_growth: r.pivot_growth,
        condition_estimate: r.condition_estimate,
        perturbations: r.perturbations,
        refine_iters: r.refine_iters,
        escalations: r.escalations,
        repairs: r.repairs,
        probe_residual: r.last_residual,
    })
}

/// The rescue block (schema v7): ladder rung 5 driven once per bench run
/// on a deterministic fixed-order-exhausted refactor — the healthy twin
/// of a zero-diagonal-band matrix is factored (pinning the static order),
/// then restamped with the adversarial values whose structurally zeroed
/// diagonals defeat perturbation *and* re-equilibration, so the threshold
/// partial-pivoting rescue ([`crate::numeric::pivlu`]) must fire. The
/// cold `rescue_ms` (pivoting factorization + full pipeline rebuild) is
/// reported beside the post-rescue `refactor_ms` (the same values on the
/// rescued order), making the hot-swap's amortization measurable per run.
#[derive(Debug, Clone)]
pub struct RescueReport {
    /// Rescues the driver's single exhausted refactor recorded (must be 1).
    pub rescues: u64,
    /// Pivot rows the rescue moved off the static choice.
    pub swapped_pivots: u64,
    /// Wall-clock of the cold rescue (pivoting factorization + symbolic
    /// rebuild + engine rerun), ms.
    pub rescue_ms: f64,
    /// Median wall-clock of the post-rescue fast-path refactor on the
    /// rescued order, ms.
    pub refactor_ms: f64,
    /// Scaled probe residual the accepted rescue achieved.
    pub residual: f64,
}

/// Drive ladder rung 5 on the deterministic exhaustion fixture and capture
/// the counters. Natural ordering and no scaling keep the twin's matching
/// at identity, so the adversarial restamp's zeroed diagonals are
/// guaranteed to land on pivots and cascade past every fixed-order rung.
pub fn rescue_report() -> anyhow::Result<RescueReport> {
    use crate::order::FillOrdering;

    let a = gen::zero_diagonal_band(96, 48, 20260808);
    let twin = gen::dominant_restamp(&a, 7);
    let opts = GluOptions {
        ordering: FillOrdering::Natural,
        scale: false,
        ..Default::default()
    };
    let mut solver = GluSolver::factor(&twin, &opts)?;
    solver.refactor(&a)?;
    let st = solver.stats();
    anyhow::ensure!(
        st.robustness.rescues == 1,
        "the fixed-order ladder must exhaust into exactly one rescue"
    );
    anyhow::ensure!(
        st.symbolic_runs == 2,
        "the rescue must rebuild the symbolic pipeline exactly once"
    );
    let rescues = st.robustness.rescues;
    let swapped_pivots = st.robustness.rescued_pivots;
    let rescue_ms = st.robustness.rescue_ms;
    let residual = st.robustness.last_residual;

    // The same adversarial values again: now a plain fast-path refactor
    // on the rescued order — its cost beside `rescue_ms` is the payoff.
    let post = measure(1, 3, || solver.refactor(&a).expect("post-rescue refactor"));
    anyhow::ensure!(
        solver.stats().robustness.rescues == 1,
        "the rescued order must not re-rescue"
    );
    Ok(RescueReport {
        rescues,
        swapped_pivots,
        rescue_ms,
        refactor_ms: post.median_ms(),
        residual,
    })
}

/// The symbolic block (schema v6): cold-start anatomy of the
/// once-per-pattern phase. Serial fill+detect+levelize against the
/// wave-parallel discovery on the persistent worker pool at each requested
/// thread count, plus the cold pipeline against the incremental patch on a
/// one-entry structural delta of the same pattern — the two fast paths the
/// SolverPool miss path takes.
#[derive(Debug, Clone)]
pub struct SymbolicReport {
    /// Min wall-clock of one serial symbolic run (fill + GLU3.0 detect +
    /// levelize), ms.
    pub serial_ms: f64,
    /// Thread counts the parallel path was measured at.
    pub threads: Vec<usize>,
    /// Min wall-clock of one fused parallel symbolic run per thread count
    /// (same order as `threads`), ms.
    pub parallel_ms: Vec<f64>,
    /// Min wall-clock of the cold serial symbolic run on the delta
    /// fixture's full pattern, ms.
    pub cold_ms: f64,
    /// Min wall-clock of the incremental patch covering the same delta, ms.
    pub incremental_ms: f64,
    /// Columns of the delta fixture whose raw structure changed.
    pub changed_columns: usize,
    /// Columns the patch actually recomputed (taint closure size).
    pub recomputed_columns: usize,
}

impl SymbolicReport {
    /// `serial / parallel` at the largest measured thread count (≥ 1.0 is
    /// the acceptance bar on the 100×100 AMD grid at 4 threads).
    pub fn speedup_parallel(&self) -> f64 {
        self.parallel_ms
            .last()
            .map_or(0.0, |&p| self.serial_ms / p.max(1e-9))
    }

    /// `cold / incremental` (≥ 5.0 is the acceptance bar).
    pub fn speedup_incremental(&self) -> f64 {
        self.cold_ms / self.incremental_ms.max(1e-9)
    }
}

/// Find one coordinate inside the fill envelope but absent from `a`: the
/// structural delta a patch handles at minimum cost (the new entry is
/// already in the filled pattern, so exactly the changed column is
/// recomputed and nothing cascades).
fn fill_envelope_entry(a: &Csc, sym: &SymbolicFill) -> Option<(usize, usize)> {
    for j in 0..a.ncols() {
        let (frows, _) = sym.filled.col(j);
        let (arows, _) = a.col(j);
        let mut ai = 0usize;
        for &r in frows {
            while ai < arows.len() && arows[ai] < r {
                ai += 1;
            }
            if ai >= arows.len() || arows[ai] != r {
                return Some((r, j));
            }
        }
    }
    None
}

/// Measure the symbolic block: AMD-permute the matrix (so the fixture
/// matches what the solver's own pipeline analyzes), race serial vs
/// parallel symbolic, then cold vs incremental on a fill-envelope delta.
pub fn symbolic_report(spec: &BenchSpec) -> anyhow::Result<SymbolicReport> {
    use crate::depend::{glu3, levelize};

    let p = crate::order::amd::amd_order(&spec.a)?;
    let a = spec.a.permute(p.as_scatter(), p.as_scatter());
    let mut ws = FillWorkspace::new();

    let serial = measure(spec.warmup, spec.iters, || {
        let sym = symbolic_fill_with(&a, &mut ws).expect("serial symbolic");
        let deps = glu3::detect(&sym.filled);
        std::hint::black_box(levelize(&deps));
    });

    let threads = spec.thread_counts.clone();
    let mut parallel_ms = Vec::with_capacity(threads.len());
    for &t in &threads {
        let pool = WorkerPool::new(t);
        let par = measure(spec.warmup, spec.iters, || {
            std::hint::black_box(
                parallel_symbolic(&a, &pool, &mut ws).expect("parallel symbolic"),
            );
        });
        parallel_ms.push(par.min * 1e3);
    }

    // The delta fixture: one entry inside the fill envelope. Any matrix
    // worth benching has fill; refuse rather than silently bench a
    // degenerate fixture.
    let base = symbolic_fill_with(&a, &mut ws)?;
    let (er, ec) = fill_envelope_entry(&a, &base)
        .ok_or_else(|| anyhow::anyhow!("bench fixture has no fill-in"))?;
    let a2 = gen::with_entry(&a, er, ec, -1e-3);
    let budget = (a.ncols() / 4).max(4);
    let changed = changed_columns(a.colptr(), a.rowidx(), &a2, budget)
        .ok_or_else(|| anyhow::anyhow!("delta fixture exceeded the patch budget"))?;

    let cold = measure(spec.warmup, spec.iters, || {
        let sym = symbolic_fill_with(&a2, &mut ws).expect("cold symbolic");
        let deps = glu3::detect(&sym.filled);
        std::hint::black_box(levelize(&deps));
    });
    let mut recomputed = 0usize;
    let incremental = measure(spec.warmup, spec.iters, || {
        let patch = patch_symbolic(&base, &a2, &changed, &mut ws).expect("patch symbolic");
        recomputed = patch.recomputed;
    });

    Ok(SymbolicReport {
        serial_ms: serial.min * 1e3,
        threads,
        parallel_ms,
        cold_ms: cold.min * 1e3,
        incremental_ms: incremental.min * 1e3,
        changed_columns: changed.len(),
        recomputed_columns: recomputed,
    })
}

/// The pool-vs-spawn head-to-head (same schedule, same arithmetic).
#[derive(Debug, Clone)]
pub struct SpawnBaseline {
    pub threads: usize,
    /// Min wall-clock of the per-level-spawn baseline factor, ms.
    pub spawn_per_level_ms: f64,
    /// Min wall-clock of the persistent-pool factor, ms.
    pub pool_ms: f64,
}

impl SpawnBaseline {
    /// How much the persistent pool wins by (≥ 2.0 is the acceptance bar).
    pub fn speedup(&self) -> f64 {
        self.spawn_per_level_ms / self.pool_ms.max(1e-9)
    }
}

/// The batched value-plane head-to-head (schema v8): one solver on the
/// batched parallel right-looking engine, timing `B` looped
/// [`GluSolver::refactor`] calls against one [`GluSolver::refactor_batch`]
/// schedule walk, and `B` single-RHS [`GluSolver::solve`] calls against
/// one blocked [`GluSolver::solve_many_into`] trisolve walk, per batch
/// size. Also carries the trisolve-variant histogram: which of
/// sequential / level-set / sync-free the timed solvers actually ran.
#[derive(Debug, Clone)]
pub struct BatchedReport {
    pub threads: usize,
    /// The batch sizes measured (index-aligned with the clock arrays).
    pub batch_sizes: Vec<usize>,
    /// Min wall-clock of `B` looped refactors, ms, per batch size.
    pub looped_refactor_ms: Vec<f64>,
    /// Min wall-clock of one `refactor_batch` over `B` planes, ms.
    pub batched_refactor_ms: Vec<f64>,
    /// Min wall-clock of `B` looped single-RHS solves, ms.
    pub looped_solve_ms: Vec<f64>,
    /// Min wall-clock of one blocked `solve_many_into` over `B` RHS, ms.
    pub batched_solve_ms: Vec<f64>,
    /// Trisolve-variant labels seen across the timed solvers…
    pub variant_labels: Vec<String>,
    /// …and how many solvers ran each (index-aligned with the labels).
    pub variant_counts: Vec<u64>,
}

impl BatchedReport {
    /// Looped / batched refactor wall-clock ratio at batch size `b`
    /// (≥ 1.3 at the largest batch is the acceptance bar). NaN if `b`
    /// was not measured.
    pub fn refactor_speedup(&self, b: usize) -> f64 {
        match self.batch_sizes.iter().position(|&x| x == b) {
            Some(i) => self.looped_refactor_ms[i] / self.batched_refactor_ms[i].max(1e-9),
            None => f64::NAN,
        }
    }

    /// Looped / blocked solve wall-clock ratio at batch size `b`.
    pub fn solve_speedup(&self, b: usize) -> f64 {
        match self.batch_sizes.iter().position(|&x| x == b) {
            Some(i) => self.looped_solve_ms[i] / self.batched_solve_ms[i].max(1e-9),
            None => f64::NAN,
        }
    }

    /// The largest batch size measured.
    pub fn max_batch(&self) -> usize {
        self.batch_sizes.iter().copied().max().unwrap_or(1)
    }

    /// Count one solver having run trisolve variant `label` (empty labels
    /// — a solver that never solved — are ignored).
    pub fn count_variant(&mut self, label: &str) {
        if label.is_empty() {
            return;
        }
        match self.variant_labels.iter().position(|l| l == label) {
            Some(i) => self.variant_counts[i] += 1,
            None => {
                self.variant_labels.push(label.to_string());
                self.variant_counts.push(1);
            }
        }
    }
}

/// Run the batched head-to-head: factor `spec.a` once on the parallel
/// right-looking engine at the largest requested thread count, then per
/// batch size time looped-vs-batched refactor and looped-vs-blocked
/// solve over value-scaled copies of the matrix (the transient-analysis
/// shape: one pattern, `B` Newton-step Jacobians / stacked right-hand
/// sides).
pub fn batched_report(spec: &BenchSpec) -> anyhow::Result<BatchedReport> {
    let threads = spec.thread_counts.iter().copied().max().unwrap_or(1);
    let opts = GluOptions {
        engine: NumericEngine::ParallelRightLooking { threads },
        ..Default::default()
    };
    let mut solver = GluSolver::factor(&spec.a, &opts)?;
    let n = spec.a.nrows();
    let batch_sizes = vec![1usize, 4, 16];
    let maxb = *batch_sizes.last().expect("non-empty batch sizes");
    let mats: Vec<Csc> = (0..maxb)
        .map(|p| {
            let mut m = spec.a.clone();
            for v in m.values_mut() {
                *v *= 1.0 + 0.01 * (p as f64 + 1.0);
            }
            m
        })
        .collect();
    let rhs: Vec<Vec<f64>> = (0..maxb)
        .map(|k| (0..n).map(|i| 1.0 + ((i * 7 + k) % 31) as f64 / 31.0).collect())
        .collect();

    let mut report = BatchedReport {
        threads,
        batch_sizes: batch_sizes.clone(),
        looped_refactor_ms: Vec::new(),
        batched_refactor_ms: Vec::new(),
        looped_solve_ms: Vec::new(),
        batched_solve_ms: Vec::new(),
        variant_labels: Vec::new(),
        variant_counts: Vec::new(),
    };
    for &bsz in &batch_sizes {
        let refs: Vec<&Csc> = mats[..bsz].iter().collect();
        let looped = measure(spec.warmup, spec.iters, || {
            for a in &refs {
                solver.refactor(a).expect("bench looped refactor");
            }
        });
        let batched = measure(spec.warmup, spec.iters, || {
            solver.refactor_batch(&refs).expect("bench batched refactor")
        });
        let block = &rhs[..bsz];
        let looped_solve = measure(spec.warmup, spec.iters.max(3), || {
            for b in block {
                solver.solve(b).expect("bench looped solve");
            }
        });
        let mut out = vec![vec![0.0; n]; bsz];
        let batched_solve = measure(spec.warmup, spec.iters.max(3), || {
            solver
                .solve_many_into(block, &mut out)
                .expect("bench blocked solve")
        });
        report.looped_refactor_ms.push(looped.min * 1e3);
        report.batched_refactor_ms.push(batched.min * 1e3);
        report.looped_solve_ms.push(looped_solve.min * 1e3);
        report.batched_solve_ms.push(batched_solve.min * 1e3);
    }
    report.count_variant(solver.stats().trisolve_variant);
    Ok(report)
}

/// Full report, serializable with [`BenchReport::to_json`].
#[derive(Debug, Clone)]
pub struct BenchReport {
    pub matrix: String,
    pub n: usize,
    pub nnz: usize,
    pub host_threads: usize,
    pub samples: Vec<EngineSample>,
    pub baseline: SpawnBaseline,
    pub plan: PlanReport,
    pub refactor_loop: RefactorLoopReport,
    pub schedule: ScheduleReport,
    pub robustness: RobustnessReport,
    pub rescue: RescueReport,
    pub symbolic: SymbolicReport,
    pub batched: BatchedReport,
}

/// Run the whole harness over `spec`.
pub fn run(spec: &BenchSpec) -> anyhow::Result<BenchReport> {
    let a = &spec.a;
    let n = a.nrows();
    let b: Vec<f64> = (0..n).map(|i| 1.0 + ((i % 97) as f64) / 97.0).collect();
    let mut a2 = a.clone();
    for v in a2.values_mut() {
        *v *= 1.1;
    }

    let mut engines: Vec<(String, NumericEngine)> = vec![
        ("simulated-gpu".into(), NumericEngine::SimulatedGpu),
        ("leftlook".into(), NumericEngine::LeftLookingCpu),
        ("rightlook".into(), NumericEngine::RightLookingCpu),
        (
            "schedule".into(),
            NumericEngine::Schedule {
                backend: ExecBackend::Virtual,
            },
        ),
    ];
    for &t in &spec.thread_counts {
        engines.push(("parlu".to_string(), NumericEngine::ParallelCpu { threads: t }));
        engines.push((
            "parrl".to_string(),
            NumericEngine::ParallelRightLooking { threads: t },
        ));
    }

    let mut samples = Vec::with_capacity(engines.len());
    let mut plan: Option<PlanReport> = None;
    let mut schedule: Option<ScheduleReport> = None;
    let mut variant_labels: Vec<&'static str> = Vec::new();
    for (name, engine) in engines {
        let threads = engine.threads();
        let opts = GluOptions {
            engine,
            ..Default::default()
        };
        let factor_ms = measure(spec.warmup, spec.iters, || {
            GluSolver::factor(a, &opts).expect("bench factor")
        })
        .median_ms();
        let mut solver = GluSolver::factor(a, &opts)?;
        let refactor_ms = measure(spec.warmup, spec.iters, || {
            solver.refactor(&a2).expect("bench refactor")
        })
        .median_ms();
        let solve_ms = measure(spec.warmup, spec.iters.max(3), || {
            solver.solve(&b).expect("bench solve")
        })
        .median_ms();
        // The plan block comes from the first solver the sweep builds (all
        // engines share the default policy, so any solver's plan serves) —
        // no extra factorization just for the report.
        if plan.is_none() {
            plan = Some(plan_report(&solver));
        }
        // The schedule block comes from the schedule-engine solver (the
        // only one whose stats carry a per-launch execution report).
        if schedule.is_none() {
            schedule = schedule_report(&solver);
        }
        // The trisolve-variant histogram: what this solver's solves ran.
        variant_labels.push(solver.stats().trisolve_variant);
        samples.push(EngineSample {
            engine: name,
            threads,
            factor_ms,
            refactor_ms,
            solve_ms,
        });
    }

    let baseline = spawn_vs_pool(spec)?;
    let refactor_loop = refactor_loop(spec)?;
    let robustness = robustness_report()?;
    let rescue = rescue_report()?;
    let symbolic = symbolic_report(spec)?;
    let mut batched = batched_report(spec)?;
    for label in variant_labels {
        batched.count_variant(label);
    }
    let plan = plan.expect("at least one engine sampled");
    let schedule = schedule.expect("schedule engine sampled");

    Ok(BenchReport {
        matrix: spec.label.clone(),
        n,
        nnz: a.nnz(),
        host_threads: std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1),
        samples,
        baseline,
        plan,
        refactor_loop,
        schedule,
        robustness,
        rescue,
        symbolic,
        batched,
    })
}

/// The refactor-loop head-to-head: AMD-permute the matrix, build one plan
/// (and time its one-time scatter map build), then run `iterations`
/// value-restamped refactors through the indexed engine and through the
/// search-based baseline — same plan, same persistent pool, same stamped
/// values, so the per-iteration gap is purely the removed position
/// resolution and CAS traffic.
pub fn refactor_loop(spec: &BenchSpec) -> anyhow::Result<RefactorLoopReport> {
    use crate::depend::{glu3, levelize};
    use crate::gpusim::{DeviceConfig, Policy};
    use crate::plan::FactorPlan;

    let threads = spec.thread_counts.iter().copied().max().unwrap_or(1);
    let p = crate::order::amd::amd_order(&spec.a)?;
    let a = spec.a.permute(p.as_scatter(), p.as_scatter());
    let sym = symbolic_fill(&a)?;
    let levels = levelize(&glu3::detect(&sym.filled));
    let plan = FactorPlan::from_levels(&sym, levels, &Policy::glu3(), &DeviceConfig::titan_x());
    let pool = WorkerPool::new(threads);

    // The pattern-time cost the loop amortizes, paid exactly once.
    let t0 = std::time::Instant::now();
    let _ = plan.scatter(&sym.filled);
    let scatter_build_ms = t0.elapsed().as_secs_f64() * 1e3;

    let mut lu = sym.filled.clone();
    let baseline_vals = lu.values().to_vec();
    let iterations = spec.iters.max(3);
    let mut indexed_ms = Vec::with_capacity(iterations);
    let mut search_ms = Vec::with_capacity(iterations);
    for it in 0..spec.warmup + iterations {
        lu.values_mut().copy_from_slice(&baseline_vals);
        let t = std::time::Instant::now();
        parrl::refactor_in_place(&mut lu, &plan, &pool, &mut PivotMonitor::new())?;
        if it >= spec.warmup {
            indexed_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }
    }
    for it in 0..spec.warmup + iterations {
        lu.values_mut().copy_from_slice(&baseline_vals);
        let t = std::time::Instant::now();
        parrl::refactor_in_place_search(&mut lu, &plan, &pool, &mut PivotMonitor::new())?;
        if it >= spec.warmup {
            search_ms.push(t.elapsed().as_secs_f64() * 1e3);
        }
    }

    Ok(RefactorLoopReport {
        threads,
        iterations,
        scatter_build_ms,
        indexed_ms,
        search_ms,
        atomic_commits_avoided: plan.atomic_commits_avoided(),
    })
}

/// Extract the report's plan block from an already-factored solver:
/// per-level mode histogram plus the preprocessing stage timings.
pub fn plan_report(solver: &GluSolver) -> PlanReport {
    let st = solver.stats();
    let (modes_small, modes_large, modes_stream) = solver.plan().mode_histogram();
    PlanReport {
        levels: solver.plan().num_levels(),
        modes_small,
        modes_large,
        modes_stream,
        build_ms: st.plan_ms,
        symbolic_ms: st.symbolic_ms,
        fillin_ms: st.fillin_ms,
        detect_ms: st.detect_ms,
        levelize_ms: st.levelize_ms,
    }
}

/// The isolated head-to-head: AMD-permute the matrix (the engines' default
/// preprocessing), compute the U-pattern schedule **once**, then time
/// pool-based [`parlu::factor_with`] against the seed's
/// [`parlu::factor_spawn_per_level_with`] at the largest requested thread
/// count. Identical schedule, identical column kernel — the measured gap
/// is the per-level spawn/join (plus its per-level workspace allocation)
/// that the persistent pool eliminates.
pub fn spawn_vs_pool(spec: &BenchSpec) -> anyhow::Result<SpawnBaseline> {
    let threads = spec.thread_counts.iter().copied().max().unwrap_or(1);
    let p = crate::order::amd::amd_order(&spec.a)?;
    let a = spec.a.permute(p.as_scatter(), p.as_scatter());
    let sym = symbolic_fill(&a)?;
    let levels = parlu::leftlook_levels(&sym);
    let n = a.nrows();

    let pool = WorkerPool::new(threads);
    let mut works = vec![vec![0.0f64; n]; pool.threads()];
    let pool_stats = measure(spec.warmup, spec.iters, || {
        parlu::factor_with(&sym, &levels, &pool, &mut works).expect("pool factor")
    });
    let spawn_stats = measure(spec.warmup, spec.iters, || {
        parlu::factor_spawn_per_level_with(&sym, &levels, threads).expect("spawn factor")
    });

    Ok(SpawnBaseline {
        threads,
        spawn_per_level_ms: spawn_stats.min * 1e3,
        pool_ms: pool_stats.min * 1e3,
    })
}

pub(crate) fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

/// Scientific-notation variant for quantities spanning many decades
/// (growth factors, condition proxies, probe residuals), where fixed
/// 6-decimal formatting would flatten e.g. `1e-12` to `0.000000`.
/// Rust's `{:e}` output (`1.5e-12`, `2e0`) is valid JSON number syntax.
pub(crate) fn json_num_sci(v: f64) -> String {
    if v.is_finite() {
        format!("{v:e}")
    } else {
        "null".to_string()
    }
}

/// Escape a string for embedding in a JSON document (labels come from the
/// CLI's `--matrix` argument, which can be an arbitrary file path).
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a slice of ms samples as a JSON number array.
pub(crate) fn json_num_array(xs: &[f64]) -> String {
    let items: Vec<String> = xs.iter().map(|&v| json_num(v)).collect();
    format!("[{}]", items.join(", "))
}

/// Render a slice of cycle counts as a JSON integer array.
pub(crate) fn json_u64_array(xs: &[u64]) -> String {
    let items: Vec<String> = xs.iter().map(|v| v.to_string()).collect();
    format!("[{}]", items.join(", "))
}

/// Render a slice of strings as a JSON string array.
pub(crate) fn json_str_array(xs: &[String]) -> String {
    let items: Vec<String> = xs.iter().map(|s| format!("\"{}\"", json_str(s))).collect();
    format!("[{}]", items.join(", "))
}

impl BenchReport {
    /// Hand-rolled JSON (no serde in the offline vendored crate set).
    /// Schema `glu3-bench-numeric-v8` (v2 added the `plan` block, v3 the
    /// `refactor_loop` block, v4 the `schedule` block, v5 the
    /// `robustness` block, v6 the `symbolic` block and the plan block's
    /// `fillin_ms`, v7 the `rescue` block, v8 the `batched` block);
    /// validated by the CI smoke job.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"glu3-bench-numeric-v8\",\n");
        s.push_str(&format!("  \"matrix\": \"{}\",\n", json_str(&self.matrix)));
        s.push_str(&format!("  \"n\": {},\n", self.n));
        s.push_str(&format!("  \"nnz\": {},\n", self.nnz));
        s.push_str(&format!("  \"host_threads\": {},\n", self.host_threads));
        s.push_str("  \"results\": [\n");
        for (i, r) in self.samples.iter().enumerate() {
            let sep = if i + 1 == self.samples.len() { "" } else { "," };
            s.push_str(&format!(
                "    {{\"engine\": \"{}\", \"threads\": {}, \"factor_ms\": {}, \
                 \"refactor_ms\": {}, \"solve_ms\": {}}}{}\n",
                json_str(&r.engine),
                r.threads,
                json_num(r.factor_ms),
                json_num(r.refactor_ms),
                json_num(r.solve_ms),
                sep
            ));
        }
        s.push_str("  ],\n");
        s.push_str(&format!(
            "  \"spawn_baseline\": {{\"threads\": {}, \"spawn_per_level_ms\": {}, \
             \"pool_ms\": {}, \"speedup\": {}}},\n",
            self.baseline.threads,
            json_num(self.baseline.spawn_per_level_ms),
            json_num(self.baseline.pool_ms),
            json_num(self.baseline.speedup())
        ));
        s.push_str(&format!(
            "  \"plan\": {{\"levels\": {}, \"mode_histogram\": {{\"small\": {}, \
             \"large\": {}, \"stream\": {}}}, \"build_ms\": {}, \"symbolic_ms\": {}, \
             \"fillin_ms\": {}, \"detect_ms\": {}, \"levelize_ms\": {}}},\n",
            self.plan.levels,
            self.plan.modes_small,
            self.plan.modes_large,
            self.plan.modes_stream,
            json_num(self.plan.build_ms),
            json_num(self.plan.symbolic_ms),
            json_num(self.plan.fillin_ms),
            json_num(self.plan.detect_ms),
            json_num(self.plan.levelize_ms)
        ));
        let rl = &self.refactor_loop;
        s.push_str(&format!(
            "  \"refactor_loop\": {{\"threads\": {}, \"iterations\": {}, \
             \"scatter_build_ms\": {}, \"atomic_commits_avoided\": {}, \
             \"indexed_ms\": {}, \"search_ms\": {}, \"indexed_median_ms\": {}, \
             \"search_median_ms\": {}, \"speedup\": {}}},\n",
            rl.threads,
            rl.iterations,
            json_num(rl.scatter_build_ms),
            rl.atomic_commits_avoided,
            json_num_array(&rl.indexed_ms),
            json_num_array(&rl.search_ms),
            json_num(rl.indexed_median_ms()),
            json_num(rl.search_median_ms()),
            json_num(rl.speedup())
        ));
        let sc = &self.schedule;
        s.push_str(&format!(
            "  \"schedule\": {{\"levels\": {}, \"total_launches\": {}, \
             \"kernels\": {}, \"executed_cycles\": {}, \"simulated_cycles\": {}, \
             \"executed_total\": {}, \"simulated_total\": {}, \"cycle_delta\": {}}},\n",
            sc.levels,
            sc.total_launches,
            json_str_array(&sc.kernels),
            json_u64_array(&sc.executed_cycles),
            json_u64_array(&sc.simulated_cycles),
            sc.executed_total(),
            sc.simulated_total(),
            sc.cycle_delta()
        ));
        let rb = &self.robustness;
        s.push_str(&format!(
            "  \"robustness\": {{\"pivot_growth\": {}, \"condition_estimate\": {}, \
             \"perturbations\": {}, \"refine_iters\": {}, \"escalations\": {}, \
             \"repairs\": {}, \"probe_residual\": {}}},\n",
            json_num_sci(rb.pivot_growth),
            json_num_sci(rb.condition_estimate),
            rb.perturbations,
            rb.refine_iters,
            rb.escalations,
            rb.repairs,
            json_num_sci(rb.probe_residual)
        ));
        let rs = &self.rescue;
        s.push_str(&format!(
            "  \"rescue\": {{\"rescues\": {}, \"swapped_pivots\": {}, \
             \"rescue_ms\": {}, \"refactor_ms\": {}, \"residual\": {}}},\n",
            rs.rescues,
            rs.swapped_pivots,
            json_num(rs.rescue_ms),
            json_num(rs.refactor_ms),
            json_num_sci(rs.residual)
        ));
        let bt = &self.batched;
        let sizes_u64: Vec<u64> = bt.batch_sizes.iter().map(|&b| b as u64).collect();
        let variants: Vec<String> = bt
            .variant_labels
            .iter()
            .zip(&bt.variant_counts)
            .map(|(l, c)| format!("\"{}\": {}", json_str(l), c))
            .collect();
        let maxb = bt.max_batch();
        s.push_str(&format!(
            "  \"batched\": {{\"threads\": {}, \"batch_sizes\": {}, \
             \"looped_refactor_ms\": {}, \"batched_refactor_ms\": {}, \
             \"looped_solve_ms\": {}, \"batched_solve_ms\": {}, \
             \"refactor_speedup_at_max\": {}, \"solve_speedup_at_max\": {}, \
             \"trisolve_variants\": {{{}}}}},\n",
            bt.threads,
            json_u64_array(&sizes_u64),
            json_num_array(&bt.looped_refactor_ms),
            json_num_array(&bt.batched_refactor_ms),
            json_num_array(&bt.looped_solve_ms),
            json_num_array(&bt.batched_solve_ms),
            json_num(bt.refactor_speedup(maxb)),
            json_num(bt.solve_speedup(maxb)),
            variants.join(", ")
        ));
        let sy = &self.symbolic;
        let threads_u64: Vec<u64> = sy.threads.iter().map(|&t| t as u64).collect();
        s.push_str(&format!(
            "  \"symbolic\": {{\"serial_ms\": {}, \"threads\": {}, \
             \"parallel_ms\": {}, \"speedup_parallel\": {}, \"cold_ms\": {}, \
             \"incremental_ms\": {}, \"speedup_incremental\": {}, \
             \"changed_columns\": {}, \"recomputed_columns\": {}}}\n",
            json_num(sy.serial_ms),
            json_u64_array(&threads_u64),
            json_num_array(&sy.parallel_ms),
            json_num(sy.speedup_parallel()),
            json_num(sy.cold_ms),
            json_num(sy.incremental_ms),
            json_num(sy.speedup_incremental()),
            sy.changed_columns,
            sy.recomputed_columns
        ));
        s.push_str("}\n");
        s
    }

    /// Write the JSON report to `path`.
    pub fn write_json(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
    }
}

/// Light structural validation of a `glu3-bench-numeric-v8` document:
/// required keys present (including the v2 `plan`, v3 `refactor_loop`,
/// v4 `schedule`, v5 `robustness`, v6 `symbolic`, v7 `rescue`, and v8
/// `batched` blocks), braces/brackets balanced, at least one result
/// row. (CI additionally runs it through a real JSON parser.)
pub fn validate_json_schema(s: &str) -> anyhow::Result<()> {
    for key in [
        "\"schema\": \"glu3-bench-numeric-v8\"",
        "\"matrix\"",
        "\"n\"",
        "\"nnz\"",
        "\"results\"",
        "\"engine\"",
        "\"threads\"",
        "\"factor_ms\"",
        "\"refactor_ms\"",
        "\"solve_ms\"",
        "\"spawn_baseline\"",
        "\"speedup\"",
        "\"plan\"",
        "\"levels\"",
        "\"mode_histogram\"",
        "\"small\"",
        "\"large\"",
        "\"stream\"",
        "\"build_ms\"",
        "\"symbolic_ms\"",
        "\"detect_ms\"",
        "\"levelize_ms\"",
        "\"refactor_loop\"",
        "\"iterations\"",
        "\"scatter_build_ms\"",
        "\"atomic_commits_avoided\"",
        "\"indexed_ms\"",
        "\"search_ms\"",
        "\"indexed_median_ms\"",
        "\"search_median_ms\"",
        "\"schedule\"",
        "\"total_launches\"",
        "\"kernels\"",
        "\"executed_cycles\"",
        "\"simulated_cycles\"",
        "\"executed_total\"",
        "\"simulated_total\"",
        "\"cycle_delta\"",
        "\"robustness\"",
        "\"pivot_growth\"",
        "\"condition_estimate\"",
        "\"perturbations\"",
        "\"refine_iters\"",
        "\"escalations\"",
        "\"repairs\"",
        "\"probe_residual\"",
        "\"rescue\"",
        "\"rescues\"",
        "\"swapped_pivots\"",
        "\"rescue_ms\"",
        "\"residual\"",
        "\"symbolic\"",
        "\"fillin_ms\"",
        "\"serial_ms\"",
        "\"parallel_ms\"",
        "\"speedup_parallel\"",
        "\"cold_ms\"",
        "\"incremental_ms\"",
        "\"speedup_incremental\"",
        "\"changed_columns\"",
        "\"recomputed_columns\"",
        "\"batched\"",
        "\"batch_sizes\"",
        "\"looped_refactor_ms\"",
        "\"batched_refactor_ms\"",
        "\"looped_solve_ms\"",
        "\"batched_solve_ms\"",
        "\"refactor_speedup_at_max\"",
        "\"solve_speedup_at_max\"",
        "\"trisolve_variants\"",
    ] {
        anyhow::ensure!(s.contains(key), "missing key {key}");
    }
    check_balanced(s)
}

/// Shared structural check: every `{`/`[` closed, string-aware (quotes and
/// escapes inside JSON strings don't count toward nesting).
pub(crate) fn check_balanced(s: &str) -> anyhow::Result<()> {
    let mut depth_obj = 0i64;
    let mut depth_arr = 0i64;
    let mut in_str = false;
    let mut escape = false;
    for c in s.chars() {
        if in_str {
            if escape {
                escape = false;
            } else if c == '\\' {
                escape = true;
            } else if c == '"' {
                in_str = false;
            }
        } else {
            match c {
                '"' => in_str = true,
                '{' => depth_obj += 1,
                '}' => depth_obj -= 1,
                '[' => depth_arr += 1,
                ']' => depth_arr -= 1,
                _ => {}
            }
            anyhow::ensure!(depth_obj >= 0 && depth_arr >= 0, "unbalanced nesting");
        }
    }
    anyhow::ensure!(
        depth_obj == 0 && depth_arr == 0 && !in_str,
        "unbalanced JSON document"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_plan() -> PlanReport {
        PlanReport {
            levels: 3,
            modes_small: 1,
            modes_large: 1,
            modes_stream: 1,
            build_ms: 0.25,
            symbolic_ms: 0.5,
            fillin_ms: 0.3125,
            detect_ms: 0.125,
            levelize_ms: 0.0625,
        }
    }

    fn toy_symbolic() -> SymbolicReport {
        SymbolicReport {
            serial_ms: 8.0,
            threads: vec![1, 2, 4],
            parallel_ms: vec![9.0, 5.0, 4.0],
            cold_ms: 10.0,
            incremental_ms: 0.5,
            changed_columns: 1,
            recomputed_columns: 1,
        }
    }

    fn toy_refactor_loop() -> RefactorLoopReport {
        RefactorLoopReport {
            threads: 4,
            iterations: 3,
            scatter_build_ms: 0.5,
            indexed_ms: vec![1.0, 2.0, 3.0],
            search_ms: vec![4.0, 6.0, 8.0],
            atomic_commits_avoided: 128,
        }
    }

    fn toy_schedule() -> ScheduleReport {
        ScheduleReport {
            levels: 3,
            total_launches: 5,
            kernels: vec!["level_update_64x256".into()],
            executed_cycles: vec![100, 200, 300],
            simulated_cycles: vec![150, 250, 450],
        }
    }

    fn toy_robustness() -> RobustnessReport {
        RobustnessReport {
            pivot_growth: 2.0,
            condition_estimate: 8.0,
            perturbations: 1,
            refine_iters: 2,
            escalations: 0,
            repairs: 1,
            probe_residual: 1e-12,
        }
    }

    fn toy_rescue() -> RescueReport {
        RescueReport {
            rescues: 1,
            swapped_pivots: 49,
            rescue_ms: 4.0,
            refactor_ms: 0.25,
            residual: 1e-15,
        }
    }

    fn toy_batched() -> BatchedReport {
        BatchedReport {
            threads: 4,
            batch_sizes: vec![1, 4, 16],
            looped_refactor_ms: vec![1.0, 4.0, 16.0],
            batched_refactor_ms: vec![1.0, 2.0, 8.0],
            looped_solve_ms: vec![0.5, 2.0, 8.0],
            batched_solve_ms: vec![0.5, 1.0, 4.0],
            variant_labels: vec!["sequential".into(), "level-set".into()],
            variant_counts: vec![3, 1],
        }
    }

    #[test]
    fn json_roundtrip_is_wellformed() {
        let report = BenchReport {
            matrix: "toy".into(),
            n: 4,
            nnz: 8,
            host_threads: 2,
            samples: vec![
                EngineSample {
                    engine: "leftlook".into(),
                    threads: 1,
                    factor_ms: 1.25,
                    refactor_ms: 0.5,
                    solve_ms: 0.125,
                },
                EngineSample {
                    engine: "parlu".into(),
                    threads: 4,
                    factor_ms: f64::NAN, // must serialize as null, stay valid
                    refactor_ms: 0.25,
                    solve_ms: 0.0625,
                },
            ],
            baseline: SpawnBaseline {
                threads: 4,
                spawn_per_level_ms: 10.0,
                pool_ms: 2.0,
            },
            plan: toy_plan(),
            refactor_loop: toy_refactor_loop(),
            schedule: toy_schedule(),
            robustness: toy_robustness(),
            rescue: toy_rescue(),
            symbolic: toy_symbolic(),
            batched: toy_batched(),
        };
        let json = report.to_json();
        validate_json_schema(&json).unwrap();
        assert!(json.contains("\"factor_ms\": null"));
        assert!(json.contains("\"speedup\": 5.000000"));
        assert!(json.contains("\"mode_histogram\": {\"small\": 1, \"large\": 1, \"stream\": 1}"));
        // the refactor-loop block: per-iteration arrays + medians
        assert!(json.contains("\"indexed_ms\": [1.000000, 2.000000, 3.000000]"));
        assert!(json.contains("\"search_ms\": [4.000000, 6.000000, 8.000000]"));
        assert!(json.contains("\"indexed_median_ms\": 2.000000"));
        assert!(json.contains("\"search_median_ms\": 6.000000"));
        assert!(json.contains("\"speedup\": 3.000000"));
        assert!(json.contains("\"atomic_commits_avoided\": 128"));
        // the v4 schedule block: per-level cycle arrays + totals + delta
        assert!(json.contains("\"kernels\": [\"level_update_64x256\"]"));
        assert!(json.contains("\"executed_cycles\": [100, 200, 300]"));
        assert!(json.contains("\"simulated_cycles\": [150, 250, 450]"));
        assert!(json.contains("\"executed_total\": 600"));
        assert!(json.contains("\"simulated_total\": 850"));
        assert!(json.contains("\"cycle_delta\": 250"));
        // the v5 robustness block: ladder counters + probe residual kept
        // in scientific notation so tiny residuals survive serialization
        assert!(json.contains("\"pivot_growth\": 2e0"));
        assert!(json.contains("\"perturbations\": 1"));
        assert!(json.contains("\"refine_iters\": 2"));
        assert!(json.contains("\"escalations\": 0"));
        assert!(json.contains("\"repairs\": 1"));
        assert!(json.contains("\"probe_residual\": 1e-12"));
        // the v7 rescue block: rung-5 counters, cold-vs-fast-path clocks
        assert!(json.contains(
            "\"rescue\": {\"rescues\": 1, \"swapped_pivots\": 49, \
             \"rescue_ms\": 4.000000, \"refactor_ms\": 0.250000, \
             \"residual\": 1e-15}"
        ));
        // the v6 symbolic block: thread sweep arrays + both speedups
        assert!(json.contains("\"fillin_ms\": 0.312500"));
        assert!(json.contains("\"serial_ms\": 8.000000"));
        assert!(json.contains("\"threads\": [1, 2, 4]"));
        assert!(json.contains("\"parallel_ms\": [9.000000, 5.000000, 4.000000]"));
        assert!(json.contains("\"speedup_parallel\": 2.000000"));
        assert!(json.contains("\"speedup_incremental\": 20.000000"));
        assert!(json.contains("\"changed_columns\": 1"));
        assert!(json.contains("\"recomputed_columns\": 1"));
        // the v8 batched block: per-B clock arrays, speedups at B=16,
        // and the trisolve-variant histogram
        assert!(json.contains("\"batch_sizes\": [1, 4, 16]"));
        assert!(json.contains("\"looped_refactor_ms\": [1.000000, 4.000000, 16.000000]"));
        assert!(json.contains("\"batched_refactor_ms\": [1.000000, 2.000000, 8.000000]"));
        assert!(json.contains("\"refactor_speedup_at_max\": 2.000000"));
        assert!(json.contains("\"solve_speedup_at_max\": 2.000000"));
        assert!(json.contains("\"trisolve_variants\": {\"sequential\": 3, \"level-set\": 1}"));
    }

    #[test]
    fn batched_report_speedups_and_histogram() {
        let mut bt = toy_batched();
        assert!((bt.refactor_speedup(16) - 2.0).abs() < 1e-12);
        assert!((bt.solve_speedup(16) - 2.0).abs() < 1e-12);
        assert!((bt.refactor_speedup(1) - 1.0).abs() < 1e-12);
        assert!(bt.refactor_speedup(3).is_nan(), "unmeasured batch size");
        assert_eq!(bt.max_batch(), 16);
        // the histogram merges repeats and ignores never-solved solvers
        bt.count_variant("sequential");
        bt.count_variant("");
        bt.count_variant("sync-free");
        assert_eq!(bt.variant_labels.len(), 3);
        assert_eq!(bt.variant_counts, vec![4, 1, 1]);
    }

    #[test]
    fn batched_report_measures_all_batch_sizes() {
        let bt = batched_report(&BenchSpec::smoke()).unwrap();
        assert_eq!(bt.batch_sizes, vec![1, 4, 16]);
        for arr in [
            &bt.looped_refactor_ms,
            &bt.batched_refactor_ms,
            &bt.looped_solve_ms,
            &bt.batched_solve_ms,
        ] {
            assert_eq!(arr.len(), 3);
            assert!(arr.iter().all(|&ms| ms > 0.0 && ms.is_finite()));
        }
        assert!(!bt.variant_labels.is_empty(), "the driver's solves count");
    }

    #[test]
    fn symbolic_report_speedups() {
        let sy = toy_symbolic();
        // the parallel speedup is taken at the *largest* thread count —
        // 1-thread overhead (9ms vs 8ms serial) must not hide the win
        assert!((sy.speedup_parallel() - 2.0).abs() < 1e-12);
        assert!((sy.speedup_incremental() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn symbolic_report_measures_both_fast_paths() {
        let report = symbolic_report(&BenchSpec::smoke()).unwrap();
        assert_eq!(report.threads, vec![1, 2]);
        assert_eq!(report.parallel_ms.len(), 2);
        assert!(report.serial_ms > 0.0 && report.cold_ms > 0.0);
        // the fill-envelope delta touches one column and cannot cascade
        assert_eq!(report.changed_columns, 1);
        assert_eq!(report.recomputed_columns, 1);
        // patching one of 900 columns must beat re-analyzing all of them
        assert!(
            report.speedup_incremental() > 1.0,
            "incremental {} ms vs cold {} ms",
            report.incremental_ms,
            report.cold_ms
        );
    }

    #[test]
    fn schedule_report_totals_and_delta() {
        let sc = toy_schedule();
        assert_eq!(sc.executed_total(), 600);
        assert_eq!(sc.simulated_total(), 850);
        assert_eq!(sc.cycle_delta(), 250);
        // a negative delta (executed > simulated) must serialize fine too
        let inv = ScheduleReport {
            executed_cycles: vec![900],
            simulated_cycles: vec![100],
            ..sc
        };
        assert_eq!(inv.cycle_delta(), -800);
    }

    #[test]
    fn refactor_loop_medians_and_speedup() {
        let rl = toy_refactor_loop();
        assert_eq!(rl.indexed_median_ms(), 2.0);
        assert_eq!(rl.search_median_ms(), 6.0);
        assert!((rl.speedup() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn labels_are_json_escaped() {
        let report = BenchReport {
            matrix: "runs\\grid \"v2\".mtx".into(),
            n: 1,
            nnz: 1,
            host_threads: 1,
            samples: vec![EngineSample {
                engine: "leftlook".into(),
                threads: 1,
                factor_ms: 1.0,
                refactor_ms: 1.0,
                solve_ms: 1.0,
            }],
            baseline: SpawnBaseline {
                threads: 1,
                spawn_per_level_ms: 1.0,
                pool_ms: 1.0,
            },
            plan: toy_plan(),
            refactor_loop: toy_refactor_loop(),
            schedule: toy_schedule(),
            robustness: toy_robustness(),
            rescue: toy_rescue(),
            symbolic: toy_symbolic(),
            batched: toy_batched(),
        };
        let json = report.to_json();
        validate_json_schema(&json).unwrap();
        assert!(json.contains("runs\\\\grid \\\"v2\\\".mtx"));
    }

    #[test]
    fn validator_rejects_truncation() {
        let report_json = "{\n  \"schema\": \"glu3-bench-numeric-v8\",\n  \"results\": [";
        assert!(validate_json_schema(report_json).is_err());
    }

    #[test]
    fn rescue_report_records_the_hot_swap() {
        let rs = rescue_report().unwrap();
        assert_eq!(rs.rescues, 1, "exactly one rescue per driver run");
        assert_eq!(
            rs.swapped_pivots, 49,
            "the zero-diagonal-band cascade forces band+1 pivot swaps"
        );
        assert!(rs.rescue_ms >= 0.0 && rs.rescue_ms.is_finite());
        assert!(rs.refactor_ms >= 0.0 && rs.refactor_ms.is_finite());
        assert!(
            rs.residual.is_finite() && rs.residual <= 1e-9,
            "accepted rescue above probe tolerance: {}",
            rs.residual
        );
    }

    #[test]
    fn robustness_report_records_an_in_place_repair() {
        let rb = robustness_report().unwrap();
        assert!(rb.repairs >= 1, "the zeroed pivot must trigger a repair");
        assert!(rb.perturbations >= 1, "rung 1 must fire");
        assert_eq!(rb.escalations, 0, "the well-conditioned fixture must not escalate");
        assert!(
            rb.probe_residual.is_finite() && rb.probe_residual <= 1e-9,
            "accepted repair above probe tolerance: {}",
            rb.probe_residual
        );
        assert!(rb.pivot_growth.is_finite() && rb.pivot_growth > 0.0);
        assert!(rb.condition_estimate >= 1.0);
    }

    #[test]
    fn plan_report_histogram_covers_all_levels() {
        let a = gen::grid2d(20, 20, 7);
        let solver = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let p = plan_report(&solver);
        assert!(p.levels > 1);
        assert_eq!(p.modes_small + p.modes_large + p.modes_stream, p.levels);
        for v in [p.build_ms, p.symbolic_ms, p.fillin_ms, p.detect_ms, p.levelize_ms] {
            assert!(v.is_finite() && v >= 0.0);
        }
        // v6 semantics: symbolic_ms is the whole phase, fillin a component
        assert!((p.symbolic_ms - (p.fillin_ms + p.detect_ms + p.levelize_ms)).abs() < 1e-9);
    }
}
