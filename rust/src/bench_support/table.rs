//! Plain-text table rendering for the bench harnesses — each bench prints
//! rows shaped like the paper's tables so EXPERIMENTS.md can be filled in by
//! copy-paste.

/// A simple left-aligned text table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            s
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let sep: Vec<String> = width.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }
}

/// Format milliseconds with sensible precision.
pub fn ms(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}")
    } else if x >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.3}")
    }
}

/// Format a speedup ratio like the paper ("13.0", "0.9").
pub fn ratio(x: f64) -> String {
    format!("{x:.1}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(vec!["matrix", "ms"]);
        t.row(vec!["rajat12", "2.24"]);
        t.row(vec!["G3_circuit", "878"]);
        let s = t.render();
        assert!(s.contains("| rajat12    |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn formatting() {
        assert_eq!(ms(123.4), "123");
        assert_eq!(ms(12.34), "12.34");
        assert_eq!(ms(0.1234), "0.123");
        assert_eq!(ratio(13.04), "13.0");
    }
}
