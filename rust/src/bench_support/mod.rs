//! Shared fixtures and table-formatting helpers for the bench harnesses in
//! `rust/benches/` (criterion is unavailable offline; each bench is a
//! `harness = false` binary built on these helpers plus
//! [`crate::util::timer::measure`]).

pub mod fixtures;
pub mod numeric;
pub mod service;
pub mod table;

pub use fixtures::paper_example;

use crate::sparse::gen::SuiteMatrix;

/// Which suite subset a bench runs on, from `GLU3_SET`:
/// `small` (5 matrices, seconds), `med` (default; 8 matrices),
/// `all` (the full 15, minutes — the EXPERIMENTS.md configuration).
pub fn bench_set() -> Vec<SuiteMatrix> {
    match std::env::var("GLU3_SET").as_deref() {
        Ok("small") => SuiteMatrix::SMALL.to_vec(),
        Ok("all") => SuiteMatrix::ALL.to_vec(),
        _ => SuiteMatrix::ALL[..8].to_vec(),
    }
}
