//! Test/bench fixtures, most importantly the paper's running example matrix.

use crate::sparse::{Coo, Csc};

/// The paper's running example (Fig. 1): an 8×8 circuit-like matrix,
/// reverse-engineered from the worked examples of Figs. 2–4 and 8–9:
///
/// - factorizing column 7 (0-based 6) uses columns 4 and 6 (Fig. 2), so
///   `A(3,6)` and `A(5,6)` are nonzero;
/// - column 4's L pattern contains rows 6 and 8 (Fig. 2a): `A(5,3)`,
///   `A(7,3)`;
/// - column 6's L pattern contains row 8 (Fig. 2b): `A(7,5)`;
/// - `A(5,3)` sits left of the diagonal `(5,5)` — the Fig. 8 "look left"
///   witness for the 6-depends-on-4 double-U (Fig. 4);
/// - an upper entry in column 2 of row 0 produces the second double-U
///   (`1 → 2` in Fig. 9b's 1-based labels).
///
/// Values are diagonally dominant (10 on the diagonal, −1 off) so the same
/// fixture drives numeric tests without pivoting.
pub fn paper_example() -> Csc {
    let entries: &[(usize, usize)] = &[
        (0, 0),
        (1, 0),
        (4, 0),
        (0, 1),
        (1, 1),
        (3, 1),
        (2, 2),
        (5, 2),
        (3, 3),
        (5, 3),
        (6, 3),
        (7, 3),
        (4, 4),
        (6, 4),
        (5, 5),
        (7, 5),
        (0, 6),
        (3, 6),
        (5, 6),
        (6, 6),
        (2, 7),
        (6, 7),
        (7, 7),
    ];
    let mut coo = Coo::new(8, 8);
    for &(r, c) in entries {
        let v = if r == c { 10.0 } else { -1.0 };
        coo.push(r, c, v);
    }
    coo.to_csc()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_shape() {
        let a = paper_example();
        assert_eq!(a.nrows(), 8);
        assert!(a.has_full_diagonal());
        // The key structural facts the worked examples rely on:
        assert!(a.has_entry(3, 6) && a.has_entry(5, 6)); // Fig. 2 updates
        assert!(a.has_entry(5, 3) && a.has_entry(7, 3)); // Fig. 2a L col 4
        assert!(a.has_entry(7, 5)); // Fig. 2b L col 6
    }
}
