//! Serving-core benchmark: a seeded chaos run plus a saturation sweep over
//! the [`Server`], reported as the schema-validated `BENCH_service.json`.
//!
//! Two phases:
//!
//! 1. **Chaos run** — `requests` submissions spread round-robin over
//!    `tenants` tenants (priorities cycling 0..4) and the given pattern
//!    set, unpaced, under the spec's [`FaultPlan`]. This is the
//!    acceptance surface: zero lost requests, bounded tail latency, and
//!    `symbolic_runs < requests` even while faults force ladder repairs,
//!    escalations, singular exhaustions, poisoned checkouts, and bursts.
//! 2. **Saturation sweep** — fresh fault-free servers driven at offered
//!    rates of ×0.25/×0.5/×1/×2 the chaos run's achieved throughput,
//!    with drift-free pacing, showing where admission control starts
//!    shedding and what it does to the p99/p999 tail.

use std::time::{Duration, Instant};

use crate::bench_support::numeric::{check_balanced, json_num, json_str};
use crate::coordinator::serve::{FaultPlan, ServeConfig, ServeStats, Server, TenantId, Ticket};
use crate::glu::GluOptions;
use crate::sparse::Csc;

/// What to run; see the module docs for the two phases.
pub struct ServiceBenchSpec {
    /// Report label (matrix name or suite tag).
    pub label: String,
    /// Tenants to register (priorities cycle 0..4).
    pub tenants: usize,
    /// Total chaos-run submissions (bursts add extras on top).
    pub requests: usize,
    /// Right-hand sides per request.
    pub rhs_per_request: usize,
    /// Admission-queue capacity.
    pub queue_capacity: usize,
    /// Worker threads.
    pub workers: usize,
    /// Per-request deadline, ms.
    pub deadline_ms: u64,
    /// The seeded chaos plan for phase 1 (phase 2 always runs fault-free).
    pub fault_plan: FaultPlan,
    /// Pace the chaos run to this offered rate (requests/s); `None` means
    /// unpaced (submit as fast as admission control allows).
    pub rate_rps: Option<f64>,
    /// Run the saturation sweep (phase 2); when off, `sweep` is `[]`.
    pub sweep: bool,
    /// Solver options for every server in the run.
    pub opts: GluOptions,
}

impl ServiceBenchSpec {
    /// CI-sized spec: small enough for a debug-build smoke run, big
    /// enough that coalescing, shedding, and every fault class fire.
    pub fn smoke(seed: u64) -> Self {
        ServiceBenchSpec {
            label: "smoke".to_string(),
            tenants: 4,
            requests: 96,
            rhs_per_request: 2,
            queue_capacity: 32,
            workers: 2,
            deadline_ms: 5_000,
            fault_plan: FaultPlan::chaos(seed),
            rate_rps: None,
            sweep: true,
            opts: GluOptions::default(),
        }
    }
}

/// One offered-rate point of the saturation sweep.
pub struct SweepPoint {
    pub offered_rps: f64,
    pub achieved_rps: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub rejected: u64,
    pub shed: u64,
    pub max_depth: usize,
}

/// Everything `BENCH_service.json` serializes.
pub struct ServiceReport {
    pub label: String,
    pub n: usize,
    pub nnz: usize,
    pub patterns: usize,
    pub tenants: usize,
    pub workers: usize,
    pub queue_capacity: usize,
    pub fault_seed: u64,
    pub fault_rate: f64,
    pub wall_ms: f64,
    pub stats: ServeStats,
    pub sweep: Vec<SweepPoint>,
}

fn max_sample(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(0.0, f64::max)
}

struct Driver<'a> {
    matrices: &'a [Csc],
    tenant_ids: Vec<TenantId>,
    rhs_per_request: usize,
    deadline: Duration,
}

impl Driver<'_> {
    /// Submit one request (request index `i` picks the tenant and the
    /// pattern); admission rejections are counted by the server itself.
    fn submit(&self, server: &Server, i: usize) -> Option<Ticket> {
        let a = &self.matrices[i % self.matrices.len()];
        let rhs = vec![vec![1.0; a.ncols()]; self.rhs_per_request];
        let tenant = self.tenant_ids[i % self.tenant_ids.len()];
        server
            .submit_with_deadline(tenant, a.clone(), rhs, self.deadline)
            .ok()
    }
}

fn build_server(spec: &ServiceBenchSpec, plan: FaultPlan) -> (Server, Vec<TenantId>) {
    let cfg = ServeConfig {
        queue_capacity: spec.queue_capacity,
        workers: spec.workers,
        default_deadline: Duration::from_millis(spec.deadline_ms),
        fault_plan: plan,
        ..ServeConfig::default()
    };
    let server = Server::new(spec.opts.clone(), cfg);
    let tenant_ids = (0..spec.tenants.max(1))
        .map(|i| server.tenant(&format!("tenant-{i}"), (i % 4) as u8))
        .collect();
    (server, tenant_ids)
}

/// Drive one server: submit `requests` (optionally paced to `rate_rps`),
/// wait out every ticket, shut down. Returns `(final stats, wall secs)`.
fn drive(
    spec: &ServiceBenchSpec,
    matrices: &[Csc],
    plan: FaultPlan,
    requests: usize,
    rate_rps: Option<f64>,
) -> anyhow::Result<(ServeStats, f64)> {
    let (server, tenant_ids) = build_server(spec, plan.clone());
    for a in matrices {
        server.warm(a)?;
    }
    let driver = Driver {
        matrices,
        tenant_ids,
        rhs_per_request: spec.rhs_per_request.max(1),
        deadline: Duration::from_millis(spec.deadline_ms),
    };
    let interval = rate_rps.map(|r| Duration::from_secs_f64(1.0 / r.max(1e-9)));
    let start = Instant::now();
    let mut tickets: Vec<Ticket> = Vec::with_capacity(requests);
    for i in 0..requests {
        if let Some(step) = interval {
            // Drift-free pacing: each request has an absolute start slot.
            let slot = start + step * i as u32;
            let now = Instant::now();
            if slot > now {
                std::thread::sleep(slot - now);
            }
        }
        if let Some(t) = driver.submit(&server, i) {
            // Deterministic burst injection: duplicate this submission, so
            // the queue sees same-stamp spikes for coalescing to absorb.
            if plan.burst_at(t.id()) {
                tickets.extend(driver.submit(&server, i));
            }
            tickets.push(t);
        }
    }
    // Every admitted request must resolve — success or typed error.
    for t in tickets {
        let _ = t.wait();
    }
    let wall = start.elapsed().as_secs_f64();
    Ok((server.shutdown(), wall))
}

/// Run the chaos phase and (optionally) the saturation sweep.
pub fn run_service_bench(
    spec: &ServiceBenchSpec,
    matrices: &[Csc],
) -> anyhow::Result<ServiceReport> {
    anyhow::ensure!(!matrices.is_empty(), "service bench needs at least one matrix");
    let plan = spec.fault_plan.clone();
    let (stats, wall) = drive(spec, matrices, plan, spec.requests, spec.rate_rps)?;
    let base_rps = (stats.resolved() as f64 / wall.max(1e-9)).max(1.0);

    let mut sweep = Vec::new();
    if spec.sweep {
        let per_point = spec.requests.clamp(8, 48);
        for mult in [0.25, 0.5, 1.0, 2.0] {
            let offered = base_rps * mult;
            let (st, w) = drive(spec, matrices, FaultPlan::disabled(), per_point, Some(offered))?;
            sweep.push(SweepPoint {
                offered_rps: offered,
                achieved_rps: st.completed as f64 / w.max(1e-9),
                p50_ms: st.p50_ms(),
                p99_ms: st.p99_ms(),
                p999_ms: st.p999_ms(),
                rejected: st.rejected,
                shed: st.shed,
                max_depth: st.depth.max_depth(),
            });
        }
    }

    Ok(ServiceReport {
        label: spec.label.clone(),
        n: matrices[0].ncols(),
        nnz: matrices[0].nnz(),
        patterns: matrices.len(),
        tenants: spec.tenants.max(1),
        workers: spec.workers,
        queue_capacity: spec.queue_capacity,
        fault_seed: spec.fault_plan.seed,
        fault_rate: spec.fault_plan.fault_rate(),
        wall_ms: wall * 1e3,
        stats,
        sweep,
    })
}

impl ServiceReport {
    /// Requests per second achieved by the chaos run.
    pub fn rps(&self) -> f64 {
        self.stats.resolved() as f64 / (self.wall_ms / 1e3).max(1e-9)
    }

    /// Hand-rolled JSON (no serde in the offline vendored crate set).
    /// Schema `glu3-bench-service-v1`; validated by the CI chaos job.
    pub fn to_json(&self) -> String {
        let st = &self.stats;
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"glu3-bench-service-v1\",\n");
        s.push_str(&format!("  \"label\": \"{}\",\n", json_str(&self.label)));
        s.push_str(&format!("  \"n\": {},\n", self.n));
        s.push_str(&format!("  \"nnz\": {},\n", self.nnz));
        s.push_str(&format!("  \"patterns\": {},\n", self.patterns));
        s.push_str(&format!("  \"tenants\": {},\n", self.tenants));
        s.push_str(&format!("  \"workers\": {},\n", self.workers));
        s.push_str(&format!("  \"fault_seed\": {},\n", self.fault_seed));
        s.push_str(&format!("  \"fault_rate\": {},\n", json_num(self.fault_rate)));
        s.push_str(&format!(
            "  \"throughput\": {{\"requests\": {}, \"wall_ms\": {}, \"rps\": {}}},\n",
            st.submitted,
            json_num(self.wall_ms),
            json_num(self.rps())
        ));
        s.push_str(&format!(
            "  \"latency\": {{\"count\": {}, \"p50_ms\": {}, \"p99_ms\": {}, \
             \"p999_ms\": {}, \"max_ms\": {}}},\n",
            st.latency.count(),
            json_num(st.p50_ms()),
            json_num(st.p99_ms()),
            json_num(st.p999_ms()),
            json_num(max_sample(st.latency.samples()))
        ));
        s.push_str(&format!(
            "  \"queue\": {{\"capacity\": {}, \"max_depth\": {}, \"mean_depth\": {}, \
             \"p99_depth\": {}}},\n",
            st.queue_capacity,
            st.depth.max_depth(),
            json_num(st.depth.mean()),
            json_num(st.depth.p99())
        ));
        s.push_str(&format!(
            "  \"counters\": {{\"submitted\": {}, \"completed\": {}, \"rejected\": {}, \
             \"shed\": {}, \"deadline_missed\": {}, \"failed\": {}, \"retries\": {}, \
             \"coalesced\": {}, \"degraded_checkouts\": {}, \"worker_panics\": {}, \
             \"in_flight\": {}, \"symbolic_runs\": {}, \"numeric_runs\": {}}},\n",
            st.submitted,
            st.completed,
            st.rejected,
            st.shed,
            st.deadline_missed,
            st.failed,
            st.retries,
            st.coalesced,
            st.degraded_checkouts,
            st.worker_panics,
            st.in_flight(),
            st.symbolic_runs,
            st.numeric_runs
        ));
        s.push_str(&format!(
            "  \"faults\": {{\"delays\": {}, \"repairs\": {}, \"escalations\": {}, \
             \"singulars\": {}, \"poisons\": {}, \"total\": {}}},\n",
            st.injected_delays,
            st.injected_repairs,
            st.injected_escalations,
            st.injected_singulars,
            st.injected_poisons,
            st.injected_faults()
        ));
        s.push_str("  \"sweep\": [\n");
        for (i, p) in self.sweep.iter().enumerate() {
            let sep = if i + 1 == self.sweep.len() { "" } else { "," };
            s.push_str(&format!(
                "    {{\"offered_rps\": {}, \"achieved_rps\": {}, \"p50_ms\": {}, \
                 \"p99_ms\": {}, \"p999_ms\": {}, \"rejected\": {}, \"shed\": {}, \
                 \"max_depth\": {}}}{}\n",
                json_num(p.offered_rps),
                json_num(p.achieved_rps),
                json_num(p.p50_ms),
                json_num(p.p99_ms),
                json_num(p.p999_ms),
                p.rejected,
                p.shed,
                p.max_depth,
                sep
            ));
        }
        s.push_str("  ]\n");
        s.push_str("}\n");
        s
    }

    /// Write the JSON document to `path`.
    pub fn write_json(&self, path: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))
    }
}

/// Light structural validation of a `glu3-bench-service-v1` document:
/// required keys present, braces/brackets balanced. (CI additionally runs
/// it through a real JSON parser.)
pub fn validate_service_schema(s: &str) -> anyhow::Result<()> {
    for key in [
        "\"schema\": \"glu3-bench-service-v1\"",
        "\"label\"",
        "\"tenants\"",
        "\"workers\"",
        "\"fault_seed\"",
        "\"fault_rate\"",
        "\"throughput\"",
        "\"rps\"",
        "\"latency\"",
        "\"p50_ms\"",
        "\"p99_ms\"",
        "\"p999_ms\"",
        "\"queue\"",
        "\"capacity\"",
        "\"max_depth\"",
        "\"mean_depth\"",
        "\"p99_depth\"",
        "\"counters\"",
        "\"submitted\"",
        "\"completed\"",
        "\"rejected\"",
        "\"shed\"",
        "\"deadline_missed\"",
        "\"failed\"",
        "\"retries\"",
        "\"coalesced\"",
        "\"degraded_checkouts\"",
        "\"worker_panics\"",
        "\"in_flight\"",
        "\"symbolic_runs\"",
        "\"numeric_runs\"",
        "\"faults\"",
        "\"delays\"",
        "\"repairs\"",
        "\"escalations\"",
        "\"singulars\"",
        "\"poisons\"",
        "\"sweep\"",
    ] {
        anyhow::ensure!(s.contains(key), "missing key {key}");
    }
    check_balanced(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    #[test]
    fn smoke_bench_round_trips_and_validates() {
        let mut spec = ServiceBenchSpec::smoke(20260808);
        spec.requests = 48;
        spec.sweep = false;
        let matrices = vec![
            gen::netlist(96, 5, 8, 0.1, 1, 0.2, 11),
            gen::grid2d(10, 10, 3),
        ];
        let report = run_service_bench(&spec, &matrices).unwrap();
        assert_eq!(report.stats.in_flight(), 0, "no request may be lost");
        assert!(report.stats.submitted > 0);
        assert!(
            report.stats.symbolic_runs < report.stats.submitted as usize,
            "caching must beat one-symbolic-per-request"
        );
        let json = report.to_json();
        validate_service_schema(&json).unwrap();
    }

    #[test]
    fn schema_validator_rejects_truncation() {
        let spec = ServiceBenchSpec::smoke(1);
        assert!(spec.sweep);
        let bad = "{\"schema\": \"glu3-bench-service-v1\"";
        assert!(validate_service_schema(bad).is_err());
    }
}
