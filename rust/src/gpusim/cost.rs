//! Per-warp / per-block cost formulas for the GLU kernel's two phases.
//!
//! The kernel body per column `j` (paper Fig. 11) is:
//!
//! 1. **divide phase** — `L(:,j) /= pivot`: one strided pass over `Lj`
//!    elements by the block's threads;
//! 2. **update phase** — for each subcolumn `k`: an element-wise MAC pass
//!    over the `Lj` update targets (`As(i,k) -= As(i,j)·As(j,k)`), by one
//!    warp (small/large block modes) or one whole block (stream mode).
//!
//! The kernel is *latency-bound*, not bandwidth-bound: the scatter accesses
//! into the target subcolumns are uncoalesced, so each warp iteration stalls
//! on DRAM unless enough other warps are resident on the SM to hide the
//! latency (this is exactly why the paper's occupancy engineering — Eq. 4,
//! the three modes — pays off; a bandwidth-roof model would make all modes
//! look identical). The effective stall per iteration is
//! `mem_latency / min(resident_warps_per_sm, MLP_CAP)` — Little's-law
//! latency hiding capped by the SM's memory-level parallelism.

/// Issue cycles per MAC iteration of one warp when positions are resolved
/// at run time (ld multiplier, ld/st target, ld row index plus the
/// row-match compare/branch, FMA, loop bookkeeping — Maxwell dual-issue
/// averaged).
pub const MAC_ISSUE_CYCLES: u64 = 8;

/// Issue cycles per MAC iteration when the kernel consumes the
/// pattern-time [`crate::plan::ScatterMap`] as its gather/scatter index
/// buffers: the row-match compare/branch disappears — ld destination
/// index, ld multiplier·L, ld/st target, FMA.
pub const MAC_ISSUE_CYCLES_INDEXED: u64 = 6;

/// Issue cycles per divide iteration of one warp.
pub const DIV_ISSUE_CYCLES: u64 = 6;

/// Fixed overhead per subcolumn task when positions are resolved at run
/// time (pointer setup, multiplier broadcast, the multiplier's binary
/// search, warp-level reduction of the loop bound).
pub const SUBCOL_OVERHEAD_CYCLES: u64 = 48;

/// Fixed overhead per subcolumn task with precomputed indices: the
/// multiplier position and run bounds come straight from the map —
/// pointer setup and broadcast only.
pub const SUBCOL_OVERHEAD_CYCLES_INDEXED: u64 = 24;

/// Fixed overhead per column (pivot broadcast + block-level sync between
/// divide and update phases).
pub const COLUMN_OVERHEAD_CYCLES: u64 = 96;

/// Memory-level-parallelism cap: outstanding-miss capacity per SM, in
/// warps' worth of requests (MSHR limit on Maxwell-class parts).
pub const MLP_CAP: usize = 8;

/// Effective stall cycles added to each warp iteration, given the number of
/// warps resident on the SM available to hide DRAM latency.
pub fn iter_stall_cycles(mem_latency: u64, resident_warps_per_sm: usize) -> u64 {
    mem_latency / (resident_warps_per_sm.clamp(1, MLP_CAP) as u64)
}

/// Bytes moved per MAC element: read `As(i,j)` (value), read-modify-write
/// `As(i,k)` (2 accesses), read the row index (u32).
pub fn mac_bytes_per_elem(bytes_per_value: usize) -> u64 {
    (3 * bytes_per_value + 4) as u64
}

/// Bytes moved per divide element: read+write `As(i,j)`.
pub fn div_bytes_per_elem(bytes_per_value: usize) -> u64 {
    (2 * bytes_per_value) as u64
}

/// Cycles for one subcolumn of `len` update targets processed by `threads`
/// threads, with `stall` effective stall cycles per iteration. `indexed`
/// credits the pattern-time scatter map (no multiplier search, no
/// row-match scan — see the `_INDEXED` constants).
pub fn subcol_cycles(len: usize, threads: usize, stall: u64, indexed: bool) -> u64 {
    if len == 0 {
        return 0;
    }
    let (overhead, issue) = if indexed {
        (SUBCOL_OVERHEAD_CYCLES_INDEXED, MAC_ISSUE_CYCLES_INDEXED)
    } else {
        (SUBCOL_OVERHEAD_CYCLES, MAC_ISSUE_CYCLES)
    };
    let iters = len.div_ceil(threads.max(1)) as u64;
    overhead + iters * (issue + stall)
}

/// Cycles for the divide phase of a column with `len` L entries, `threads`
/// threads, and `stall` per-iteration stall.
pub fn divide_cycles(len: usize, threads: usize, stall: u64) -> u64 {
    let iters = (len.div_ceil(threads.max(1))) as u64;
    COLUMN_OVERHEAD_CYCLES + iters * (DIV_ISSUE_CYCLES + stall)
}

/// Total bytes for a column's update phase: `n_subcols` passes over `l_len`
/// targets each.
pub fn column_update_bytes(l_len: usize, n_subcols: usize, bytes_per_value: usize) -> u64 {
    (l_len as u64) * (n_subcols as u64) * mac_bytes_per_elem(bytes_per_value)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subcol_scaling() {
        // 64 elements on one warp: 2 iterations, no stall.
        assert_eq!(
            subcol_cycles(64, 32, 0, false),
            SUBCOL_OVERHEAD_CYCLES + 2 * MAC_ISSUE_CYCLES
        );
        // 64 elements on 1024 threads: 1 iteration.
        assert_eq!(
            subcol_cycles(64, 1024, 0, false),
            SUBCOL_OVERHEAD_CYCLES + MAC_ISSUE_CYCLES
        );
        assert_eq!(subcol_cycles(0, 32, 10, false), 0);
        assert_eq!(subcol_cycles(0, 32, 10, true), 0);
    }

    #[test]
    fn more_threads_never_slower() {
        for len in [1usize, 31, 32, 33, 1000, 5000] {
            for indexed in [false, true] {
                let mut prev = u64::MAX;
                for threads in [32, 64, 128, 256, 512, 1024] {
                    let c = subcol_cycles(len, threads, 25, indexed);
                    assert!(c <= prev, "len {len} threads {threads}");
                    prev = c;
                }
            }
        }
    }

    /// The indexed kernel is credited for the removed search work: never
    /// more expensive, strictly cheaper on any nonzero task.
    #[test]
    fn indexed_credit_is_monotone() {
        for len in [1usize, 32, 1000] {
            for stall in [0u64, 25, 400] {
                let search = subcol_cycles(len, 32, stall, false);
                let indexed = subcol_cycles(len, 32, stall, true);
                assert!(indexed < search, "len {len} stall {stall}");
            }
        }
    }

    #[test]
    fn latency_hiding() {
        // One lonely warp eats the full latency; 16+ warps hide most of it.
        assert_eq!(iter_stall_cycles(400, 1), 400);
        assert_eq!(iter_stall_cycles(400, 4), 100);
        assert_eq!(iter_stall_cycles(400, 8), 50);
        // MLP cap: more warps than MSHRs cannot hide further.
        assert_eq!(iter_stall_cycles(400, 64), 50);
    }

    #[test]
    fn bytes_accounting() {
        assert_eq!(mac_bytes_per_elem(8), 28);
        assert_eq!(div_bytes_per_elem(8), 16);
        assert_eq!(column_update_bytes(10, 3, 8), 10 * 3 * 28);
    }
}
