//! Kernel-mode timing: block construction + greedy SM scheduling.
//!
//! One level = one (or, in stream mode, many) kernel launch(es). The level's
//! duration is `max(compute makespan, bandwidth roof) + launch overheads`:
//!
//! - **compute makespan** — blocks are placed greedily onto *block slots*
//!   (SM count × resident-blocks-per-SM, further capped by the Eq. (5)
//!   column-cache limit); each slot runs its blocks back-to-back. This is
//!   exactly the throughput model behind the paper's Eq. (4) reasoning:
//!   halving warps-per-block doubles resident blocks.
//! - **bandwidth roof** — the kernel is memory-bound (sparse MAC streams);
//!   a level can never finish faster than its total DRAM traffic divided by
//!   aggregate bandwidth.

use super::cost;
use super::device::DeviceConfig;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

// Mode selection and the per-column work description migrated to the shared
// plan layer (`crate::plan` is the single source of mode decisions);
// re-exported here so existing `gpusim::exec` callers keep compiling.
pub use crate::plan::{select_mode, ColumnWork, KernelMode};

/// Timing result for one level.
#[derive(Debug, Clone)]
pub struct LevelTiming {
    pub mode: KernelMode,
    pub columns: usize,
    pub max_subcols: usize,
    /// Cycles of the level (compute/bandwidth max + launches).
    pub cycles: u64,
    /// Total DRAM traffic of the level.
    pub bytes: u64,
    /// Kernel launches charged.
    pub launches: u64,
    /// Mean warp occupancy during the level (busy warp-cycles over
    /// resident capacity).
    pub occupancy: f64,
}

/// Greedy makespan of `durations` over `slots` parallel servers.
fn greedy_makespan(durations: impl Iterator<Item = u64>, slots: usize) -> u64 {
    let slots = slots.max(1);
    let mut heap: BinaryHeap<Reverse<u64>> = (0..slots).map(|_| Reverse(0u64)).collect();
    let mut makespan = 0u64;
    for d in durations {
        let Reverse(t) = heap.pop().unwrap();
        let fin = t + d;
        makespan = makespan.max(fin);
        heap.push(Reverse(fin));
    }
    makespan
}

/// Simulate one level in the given mode. `n` is the matrix dimension
/// (for the Eq. 5 cap); `launch_scale` discounts launch overhead
/// (Lee's dynamic parallelism batches launches, scale < 1); `indexed`
/// costs the kernel that consumes the pattern-time
/// [`crate::plan::ScatterMap`] as its gather/scatter index buffers
/// (no multiplier search, no row-match scan — the refactorization hot
/// path), keeping the simulator reconciled with the indexed CPU twin.
pub fn simulate_level(
    cols: &[ColumnWork],
    mode: KernelMode,
    n: usize,
    device: &DeviceConfig,
    launch_scale: f64,
    compute_scale: f64,
    indexed: bool,
) -> LevelTiming {
    let bpv = device.bytes_per_value;
    let total_bytes: u64 = cols
        .iter()
        .map(|c| {
            cost::column_update_bytes(c.l_len, c.n_subcols, bpv)
                + (c.l_len as u64) * cost::div_bytes_per_elem(bpv)
        })
        .sum();
    let mem_cycles = (total_bytes as f64 / device.mem_bytes_per_cycle) as u64;
    let mem_cap = device.max_parallel_columns(n);

    let (compute_cycles, launches, busy_warp_cycles, slots, warps_per_block): (
        u64,
        u64,
        u64,
        usize,
        usize,
    ) = match mode {
        KernelMode::SmallBlock { .. } | KernelMode::LargeBlock => {
            let w = match mode {
                KernelMode::SmallBlock { warps_per_block } => warps_per_block,
                _ => 32,
            };
            let threads = w * device.warp_size;
            let resident_blocks_per_sm = (device.max_warps_per_sm / w)
                .min(device.max_blocks_per_sm)
                .max(1);
            let slots = (device.num_sms * resident_blocks_per_sm).min(mem_cap.max(1));
            // Latency hiding: warps resident on an SM while this level runs.
            // Bounded both by the block-slot geometry and by how many blocks
            // the level actually supplies.
            let blocks_live_per_sm = resident_blocks_per_sm
                .min(cols.len().div_ceil(device.num_sms))
                .max(1);
            let hiding = (blocks_live_per_sm * w).min(device.max_warps_per_sm);
            let stall = cost::iter_stall_cycles(device.mem_latency_cycles, hiding);
            // Block duration: divide phase on all W warps, then each warp
            // serially processes ceil(S/W) subcolumn tasks.
            let durations = cols.iter().map(|c| {
                let div = cost::divide_cycles(c.l_len, threads, stall);
                let per_warp_tasks = c.n_subcols.div_ceil(w);
                let upd = per_warp_tasks as u64
                    * cost::subcol_cycles(c.l_len, device.warp_size, stall, indexed);
                div + upd
            });
            // Pipeline-fill latency is paid once per level: back-to-back
            // blocks in a slot overlap their DRAM fills.
            let makespan = greedy_makespan(durations, slots) + device.mem_latency_cycles;
            // Busy warp-cycles: warps actually doing subcolumn/div work.
            let busy: u64 = cols
                .iter()
                .map(|c| {
                    let div = cost::divide_cycles(c.l_len, threads, stall) * w as u64;
                    let upd = c.n_subcols as u64
                        * cost::subcol_cycles(c.l_len, device.warp_size, stall, indexed);
                    div + upd
                })
                .sum();
            (makespan, 1, busy, slots, w)
        }
        KernelMode::Stream => {
            // One kernel per column; one 1024-thread block per subcolumn.
            let threads = device.max_threads_per_block;
            let w = threads / device.warp_size; // 32 warps per block
            let resident_blocks_per_sm = (device.max_warps_per_sm / w)
                .min(device.max_blocks_per_sm)
                .max(1);
            let slots = (device.num_sms * resident_blocks_per_sm).min(mem_cap.max(1));
            let total_blocks: usize = cols.iter().map(|c| c.n_subcols.max(1)).sum();
            let blocks_live_per_sm = resident_blocks_per_sm
                .min(total_blocks.div_ceil(device.num_sms))
                .max(1);
            let hiding = (blocks_live_per_sm * w).min(device.max_warps_per_sm);
            let stall = cost::iter_stall_cycles(device.mem_latency_cycles, hiding);
            let block_durations = cols.iter().flat_map(|c| {
                std::iter::repeat_n(
                    cost::subcol_cycles(c.l_len, threads, stall, indexed),
                    c.n_subcols.max(1),
                )
            });
            // Pipeline-fill latency once per level (see above).
            let makespan = greedy_makespan(block_durations, slots) + device.mem_latency_cycles;
            // Divide phases: one small pass per column, pipelined over
            // streams with the update blocks; approximate by the max.
            let div_tail = cols
                .iter()
                .map(|c| cost::divide_cycles(c.l_len, threads, stall))
                .max()
                .unwrap_or(0);
            // Each update block keeps its w warps busy for the block
            // duration's issue portion.
            let busy: u64 = cols
                .iter()
                .map(|c| {
                    (c.n_subcols as u64)
                        * cost::subcol_cycles(c.l_len, threads, stall, indexed)
                        * w as u64
                        + cost::divide_cycles(c.l_len, threads, stall)
                })
                .sum();
            // Launches: one per column, dispatched over num_streams.
            let launches = cols.len() as u64;
            (makespan + div_tail, launches, busy, slots, w)
        }
    };

    // Launch overhead: stream-mode launches pipeline over the streams; the
    // level pays the serialized dispatch tail.
    let launch_cycles = match mode {
        KernelMode::Stream => {
            let per = (device.kernel_launch_cycles as f64 * launch_scale) as u64;
            launches * per / device.num_streams.max(1) as u64 + per
        }
        _ => (device.kernel_launch_cycles as f64 * launch_scale) as u64,
    };

    // The kernel is latency-bound (uncoalesced scatters): memory cost is
    // already charged per iteration via the stall model, so the aggregate
    // DRAM roof is reported but never binds at the occupancies these
    // kernels reach (see module docs / DESIGN.md §Hardware-Adaptation).
    let _ = mem_cycles;
    let cycles = (compute_cycles as f64 * compute_scale) as u64 + launch_cycles;
    let capacity =
        (slots * warps_per_block) as u64 * compute_cycles.max(1);
    let occupancy = (busy_warp_cycles as f64 / capacity as f64).min(1.0);

    LevelTiming {
        mode,
        columns: cols.len(),
        max_subcols: cols.iter().map(|c| c.n_subcols).max().unwrap_or(0),
        cycles,
        bytes: total_bytes,
        launches,
        occupancy,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dev() -> DeviceConfig {
        DeviceConfig::titan_x()
    }

    #[test]
    fn greedy_makespan_basics() {
        assert_eq!(greedy_makespan([5, 5, 5, 5].into_iter(), 2), 10);
        assert_eq!(greedy_makespan([10, 1, 1, 1].into_iter(), 2), 10);
        assert_eq!(greedy_makespan(std::iter::empty(), 4), 0);
    }

    /// Type A shape: many columns, few subcolumns each — small block must
    /// beat large block (the Table III case-1 story).
    #[test]
    fn small_block_wins_on_type_a() {
        let d = dev();
        let cols: Vec<ColumnWork> = (0..4000)
            .map(|_| ColumnWork {
                l_len: 8,
                n_subcols: 2,
            })
            .collect();
        let small = simulate_level(
            &cols,
            KernelMode::SmallBlock { warps_per_block: 2 },
            50_000,
            &d,
            1.0,
            1.0,
            false,
        );
        let large = simulate_level(&cols, KernelMode::LargeBlock, 50_000, &d, 1.0, 1.0, false);
        assert!(
            small.cycles < large.cycles,
            "small {} vs large {}",
            small.cycles,
            large.cycles
        );
    }

    /// Type C shape: few columns, many long subcolumns — stream mode must
    /// beat large block (the Table III case-2 story).
    #[test]
    fn stream_wins_on_type_c() {
        let d = dev();
        let cols: Vec<ColumnWork> = (0..4)
            .map(|_| ColumnWork {
                l_len: 3000,
                n_subcols: 400,
            })
            .collect();
        let stream = simulate_level(&cols, KernelMode::Stream, 50_000, &d, 1.0, 1.0, false);
        let large = simulate_level(&cols, KernelMode::LargeBlock, 50_000, &d, 1.0, 1.0, false);
        assert!(
            stream.cycles < large.cycles,
            "stream {} vs large {}",
            stream.cycles,
            large.cycles
        );
    }

    /// Eq. (5): a huge matrix caps concurrent columns, hurting small-block
    /// mode (the paper's G3_circuit anomaly in Table III).
    #[test]
    fn memory_cap_throttles_small_block_on_huge_n() {
        let d = dev();
        let cols: Vec<ColumnWork> = (0..6000)
            .map(|_| ColumnWork {
                l_len: 6,
                n_subcols: 2,
            })
            .collect();
        let small_small_n = simulate_level(
            &cols,
            KernelMode::SmallBlock { warps_per_block: 2 },
            30_000,
            &d,
            1.0,
            1.0,
            false,
        );
        let small_huge_n = simulate_level(
            &cols,
            KernelMode::SmallBlock { warps_per_block: 2 },
            2_000_000,
            &d,
            1.0,
            1.0,
            false,
        );
        assert!(
            small_huge_n.cycles > small_small_n.cycles * 3,
            "cap should throttle: {} vs {}",
            small_huge_n.cycles,
            small_small_n.cycles
        );
    }

    #[test]
    fn traffic_is_accounted() {
        let d = dev();
        let cols = vec![ColumnWork {
            l_len: 100,
            n_subcols: 4,
        }];
        let t = simulate_level(&cols, KernelMode::LargeBlock, 10_000, &d, 1.0, 1.0, false);
        // update: 100*4*28 bytes + divide: 100*16 bytes
        assert_eq!(t.bytes, 100 * 4 * 28 + 100 * 16);
    }

    /// The indexed (scatter-mapped) kernel is credited for the removed
    /// search work in every mode: fewer cycles, identical DRAM accounting.
    /// (Uniform columns, so the greedy placement is identical for both
    /// variants and the cycle comparison is strictly monotone.)
    #[test]
    fn indexed_kernel_is_cheaper_in_every_mode() {
        let d = dev();
        let cols: Vec<ColumnWork> = (0..200)
            .map(|_| ColumnWork {
                l_len: 24,
                n_subcols: 4,
            })
            .collect();
        for mode in [
            KernelMode::SmallBlock { warps_per_block: 4 },
            KernelMode::LargeBlock,
            KernelMode::Stream,
        ] {
            let search = simulate_level(&cols, mode, 10_000, &d, 1.0, 1.0, false);
            let indexed = simulate_level(&cols, mode, 10_000, &d, 1.0, 1.0, true);
            assert!(
                indexed.cycles < search.cycles,
                "{mode:?}: indexed {} vs search {}",
                indexed.cycles,
                search.cycles
            );
            assert_eq!(indexed.bytes, search.bytes);
            assert_eq!(indexed.launches, search.launches);
        }
    }

    #[test]
    fn occupancy_in_unit_range() {
        let d = dev();
        let cols: Vec<ColumnWork> = (0..100)
            .map(|i| ColumnWork {
                l_len: 10 + i % 50,
                n_subcols: 1 + i % 8,
            })
            .collect();
        for mode in [
            KernelMode::SmallBlock { warps_per_block: 4 },
            KernelMode::LargeBlock,
            KernelMode::Stream,
        ] {
            let t = simulate_level(&cols, mode, 10_000, &d, 1.0, 1.0, false);
            assert!((0.0..=1.0).contains(&t.occupancy), "{mode:?}: {}", t.occupancy);
        }
    }
}
