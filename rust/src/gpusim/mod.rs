//! Cycle-approximate GPU execution substrate — the testbed substitution for
//! the paper's NVIDIA GTX TITAN X (DESIGN.md §2).
//!
//! No GPU exists in this environment, so the paper's measured object — *GPU
//! kernel time under different thread/block allocation policies* — is
//! reproduced by simulation. The simulator:
//!
//! 1. **executes the real numerics** of the hybrid right-looking kernel
//!    (level-ordered Algorithm 2; results checked against the sequential
//!    engines to fp tolerance), and
//! 2. **accounts cycles** with the same occupancy arithmetic the paper
//!    reasons with: resident-warp limits per SM, block-slot limits, the
//!    Eq. (4) warps-per-block rule, the Eq. (5) column-cache memory cap,
//!    aggregate memory bandwidth, kernel-launch and one-time driver
//!    overheads, and 16-deep CUDA-stream pipelining for stream mode.
//!
//! Absolute milliseconds are not comparable to the authors' testbed; the
//! *shape* — which kernel mode wins on which level type, where GLU3.0's
//! advantage over the fixed-allocation GLU2.0 kernel grows, the stream-
//! threshold sweep of Fig. 12 — is what the benches reproduce.
//!
//! Submodules:
//! - [`device`] — device model ([`DeviceConfig::titan_x`] default).
//! - [`cost`] — per-warp/per-block cost formulas and memory traffic.
//! - [`exec`] — kernel-mode timing: block building + greedy SM scheduling.
//! - [`policy`] — solver policies: GLU3.0 adaptive, GLU2.0 fixed, Lee's
//!   enhanced GLU2.0, and ablations (Table III's case 1 / case 2).
//! - [`executor`] — level-ordered numeric factorization + timing report.
//!
//! Mode selection itself lives in [`crate::plan`]: the simulator *costs* a
//! mode-annotated [`crate::plan::FactorPlan`] rather than re-deriving the
//! per-level kernel mode (the pre-plan code kept one copy of the Eq. 4
//! decision here and another in [`policy`]).

pub mod cost;
pub mod device;
pub mod exec;
pub mod executor;
pub mod policy;

pub use device::DeviceConfig;
pub use exec::{KernelMode, LevelTiming};
pub use executor::{simulate_factorization, simulate_refactorization, SimReport};
pub use policy::Policy;
