//! GPU device model.
//!
//! Defaults follow the paper's testbed: NVIDIA GTX TITAN X (Maxwell GM200),
//! 24 SMs × 128 SPs, 12 GB GDDR5 @ 336 GB/s, ~1.0 GHz boost clock. The
//! resident-warp/block limits are the Maxwell architectural values the
//! paper's occupancy reasoning (Eqs. 4–5, Fig. 11) depends on.

/// Configuration of the simulated device.
#[derive(Debug, Clone)]
pub struct DeviceConfig {
    /// Marketing name, for reports.
    pub name: &'static str,
    /// Number of streaming multiprocessors.
    pub num_sms: usize,
    /// Max resident warps per SM (Maxwell: 64).
    pub max_warps_per_sm: usize,
    /// Max resident blocks per SM (Maxwell: 32).
    pub max_blocks_per_sm: usize,
    /// Threads per warp.
    pub warp_size: usize,
    /// Max threads per block (=> max 32 warps/block).
    pub max_threads_per_block: usize,
    /// SM clock in GHz (cycles are reported at this clock; 1.0 => 1 cycle = 1 ns).
    pub clock_ghz: f64,
    /// Aggregate DRAM bandwidth, bytes per cycle (336 GB/s at 1 GHz ≈ 336 B/cy).
    pub mem_bytes_per_cycle: f64,
    /// DRAM latency in cycles (pipeline-fill term per warp task chain).
    pub mem_latency_cycles: u64,
    /// Bytes of global memory budgeted for the per-column dense caches —
    /// the Eq. (5) numerator. The paper's kernel allocates an n-length
    /// array per column in flight; this budget caps concurrent columns.
    pub column_cache_bytes: usize,
    /// Bytes per matrix value. The paper uses f32 (Maxwell lacks f64
    /// atomics); this reproduction computes in f64 and accounts 8 B.
    pub bytes_per_value: usize,
    /// Per-kernel-launch overhead in cycles (~5 µs at 1 GHz).
    pub kernel_launch_cycles: u64,
    /// One-time driver/context setup in cycles (paper §IV: the first CUDA
    /// call took ~40% of total GPU time on ASIC_100ks).
    pub setup_cycles: u64,
    /// Number of CUDA streams available to stream mode.
    pub num_streams: usize,
}

impl DeviceConfig {
    /// The paper's testbed: GTX TITAN X (Maxwell).
    pub fn titan_x() -> Self {
        DeviceConfig {
            name: "GTX TITAN X (simulated)",
            num_sms: 24,
            max_warps_per_sm: 64,
            max_blocks_per_sm: 32,
            warp_size: 32,
            max_threads_per_block: 1024,
            clock_ghz: 1.0,
            mem_bytes_per_cycle: 336.0,
            mem_latency_cycles: 600,
            column_cache_bytes: 256 << 20,
            bytes_per_value: 8,
            kernel_launch_cycles: 2_000,
            setup_cycles: 3_000_000,
            num_streams: 16,
        }
    }

    /// Total warp contexts on the device (the Eq. 4 numerator).
    pub fn total_warps(&self) -> usize {
        self.num_sms * self.max_warps_per_sm
    }

    /// Eq. (5): maximum concurrently-factorizable columns for an n-row
    /// matrix given the column-cache budget.
    pub fn max_parallel_columns(&self, n: usize) -> usize {
        (self.column_cache_bytes / (n * self.bytes_per_value).max(1)).max(1)
    }

    /// Convert cycles to milliseconds at the configured clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.clock_ghz * 1e6)
    }

    /// The same device with every latency and overhead term zeroed —
    /// memory latency, kernel-launch cost, one-time setup — leaving the
    /// geometry (SMs, warps, block slots, cache caps) intact. The
    /// schedule executor ([`crate::runtime::executor`]) costs each
    /// *executed* launch against this device: the pure issue makespan of
    /// the real launch geometry, so the simulated-minus-executed cycle
    /// delta isolates exactly the model's latency and launch terms.
    pub fn issue_only(&self) -> DeviceConfig {
        DeviceConfig {
            mem_latency_cycles: 0,
            kernel_launch_cycles: 0,
            setup_cycles: 0,
            ..self.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn titan_x_shape() {
        let d = DeviceConfig::titan_x();
        assert_eq!(d.total_warps(), 1536);
        assert_eq!(d.max_threads_per_block / d.warp_size, 32);
        assert!((d.cycles_to_ms(1_000_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn issue_only_zeroes_latency_terms_and_keeps_geometry() {
        let d = DeviceConfig::titan_x();
        let io = d.issue_only();
        assert_eq!(io.mem_latency_cycles, 0);
        assert_eq!(io.kernel_launch_cycles, 0);
        assert_eq!(io.setup_cycles, 0);
        assert_eq!(io.num_sms, d.num_sms);
        assert_eq!(io.max_warps_per_sm, d.max_warps_per_sm);
        assert_eq!(io.total_warps(), d.total_warps());
        // a level costed on the issue-only device charges no stall: fewer
        // cycles than the full latency model on identical work
        let cols: Vec<crate::plan::ColumnWork> = (0..64)
            .map(|_| crate::plan::ColumnWork {
                l_len: 20,
                n_subcols: 3,
            })
            .collect();
        let full = crate::gpusim::exec::simulate_level(
            &cols,
            crate::plan::KernelMode::LargeBlock,
            5_000,
            &d,
            1.0,
            1.0,
            true,
        );
        let issue = crate::gpusim::exec::simulate_level(
            &cols,
            crate::plan::KernelMode::LargeBlock,
            5_000,
            &io,
            1.0,
            1.0,
            true,
        );
        assert!(issue.cycles < full.cycles, "{} vs {}", issue.cycles, full.cycles);
        assert!(issue.cycles > 0);
    }

    #[test]
    fn eq5_memory_cap() {
        let d = DeviceConfig::titan_x();
        // 256 MiB / (250k rows * 8 B) = 134 columns
        let cap = d.max_parallel_columns(250_000);
        assert!((100..200).contains(&cap), "cap {cap}");
        // small matrices are effectively uncapped
        assert!(d.max_parallel_columns(2_000) > 10_000);
    }
}
