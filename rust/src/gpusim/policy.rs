//! Solver policies: how each competing system maps levels to kernel modes.
//!
//! - [`Policy::glu3`] — the paper's adaptive three-mode policy (Eq. 4,
//!   stream threshold 16), with ablation switches for Table III's case 1
//!   (small-block disabled) and case 2 (stream disabled) and the Fig. 12
//!   threshold sweep.
//! - [`Policy::glu2_fixed`] — GLU1.0/2.0: fixed allocation, the large-block
//!   kernel for every level, one launch per level.
//! - [`Policy::lee_enhanced`] — the enhanced GLU2.0 of Lee et al. [21],
//!   approximated per its description: still the fixed 32-warp block shape
//!   (the paper: "the fixed GPU threads and memory allocation method from
//!   GLU2.0 ... is still used"), but with dynamic-parallelism kernel
//!   management (launch overhead batched, ×0.25) and batch/pipeline modes
//!   that overlap small adjacent levels (modelled as a per-level overhead
//!   reduction and subcolumn-block dispatch for sub-32-column levels at
//!   doubled per-launch cost).

use super::device::DeviceConfig;
use crate::plan::KernelMode;

/// A named kernel-mode policy.
#[derive(Debug, Clone, PartialEq)]
pub struct Policy {
    /// Report label ("GLU3.0", "GLU2.0", ...).
    pub name: String,
    /// Stream-mode threshold N (levels of size ≤ N use stream mode).
    pub stream_threshold: usize,
    /// Enable small-block mode (Table III case 1 disables it).
    pub enable_small: bool,
    /// Enable stream mode (Table III case 2 disables it).
    pub enable_stream: bool,
    /// Adaptive Eq. 4 warp allocation at all (false = GLU2.0 fixed kernel).
    pub adaptive: bool,
    /// Launch-overhead scale (dynamic parallelism batching, Lee).
    pub launch_scale: f64,
    /// Compute-makespan scale: batch/pipeline cross-level overlap
    /// (Lee's modes overlap adjacent levels; GLU3.0 synchronizes).
    pub compute_scale: f64,
}

impl Policy {
    /// The paper's GLU3.0 adaptive policy.
    pub fn glu3() -> Self {
        Policy {
            name: "GLU3.0".into(),
            stream_threshold: 16,
            enable_small: true,
            enable_stream: true,
            adaptive: true,
            launch_scale: 1.0,
            compute_scale: 1.0,
        }
    }

    /// GLU3.0 with a custom stream threshold (Fig. 12 sweep).
    pub fn glu3_with_threshold(n: usize) -> Self {
        Policy {
            name: format!("GLU3.0(N={n})"),
            stream_threshold: n,
            ..Policy::glu3()
        }
    }

    /// Table III case 1: small-block mode disabled.
    pub fn glu3_no_small() -> Self {
        Policy {
            name: "GLU3.0-case1(no small)".into(),
            enable_small: false,
            ..Policy::glu3()
        }
    }

    /// Table III case 2: stream mode disabled.
    pub fn glu3_no_stream() -> Self {
        Policy {
            name: "GLU3.0-case2(no stream)".into(),
            enable_stream: false,
            ..Policy::glu3()
        }
    }

    /// The GLU2.0 baseline: fixed thread allocation.
    pub fn glu2_fixed() -> Self {
        Policy {
            name: "GLU2.0".into(),
            stream_threshold: 0,
            enable_small: false,
            enable_stream: false,
            adaptive: false,
            launch_scale: 1.0,
            compute_scale: 1.0,
        }
    }

    /// Lee et al.'s enhanced GLU2.0 (approximation; see module docs):
    /// the *fixed* 32-warp allocation is kept (quoting the paper: "the
    /// fixed GPU threads and memory allocation method from GLU2.0 ... is
    /// still used and limiting performance"); dynamic-parallelism kernel
    /// management batches launches (x0.5) and batch/pipeline modes
    /// overlap adjacent levels (x0.9 on compute makespan) — calibrated so
    /// the Lee-vs-GLU2.0 geometric mean lands near the 1.26x the paper
    /// quotes for [21].
    pub fn lee_enhanced() -> Self {
        Policy {
            name: "Lee-eGLU2.0".into(),
            stream_threshold: 0,
            enable_small: false,
            enable_stream: false,
            adaptive: false,
            launch_scale: 0.5,
            compute_scale: 0.9,
        }
    }

    /// Kernel mode for a level of `level_size` columns. Thin delegate to
    /// [`crate::plan::mode_for`] — the plan layer is the single source of
    /// mode decisions (this used to duplicate the Eq. 4 gating inline).
    pub fn mode_for(&self, level_size: usize, device: &DeviceConfig) -> KernelMode {
        crate::plan::mode_for(self, level_size, device)
    }

    /// Per-launch overhead scale for a level.
    pub fn launch_scale_for(&self, _level_size: usize) -> f64 {
        self.launch_scale
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn glu2_is_always_large_block() {
        let d = DeviceConfig::titan_x();
        let p = Policy::glu2_fixed();
        for size in [1, 10, 100, 10_000] {
            assert_eq!(p.mode_for(size, &d), KernelMode::LargeBlock);
        }
    }

    #[test]
    fn ablations_fall_back_to_large() {
        let d = DeviceConfig::titan_x();
        let no_small = Policy::glu3_no_small();
        assert_eq!(no_small.mode_for(5000, &d), KernelMode::LargeBlock);
        assert_eq!(no_small.mode_for(4, &d), KernelMode::Stream);
        let no_stream = Policy::glu3_no_stream();
        assert_eq!(no_stream.mode_for(4, &d), KernelMode::LargeBlock);
        assert!(matches!(
            no_stream.mode_for(5000, &d),
            KernelMode::SmallBlock { .. }
        ));
    }

    #[test]
    fn glu3_adapts() {
        let d = DeviceConfig::titan_x();
        let p = Policy::glu3();
        assert_eq!(p.mode_for(8, &d), KernelMode::Stream);
        assert_eq!(p.mode_for(30, &d), KernelMode::LargeBlock);
        assert!(matches!(p.mode_for(500, &d), KernelMode::SmallBlock { .. }));
    }

    #[test]
    fn lee_keeps_fixed_allocation_with_cheaper_overheads() {
        let d = DeviceConfig::titan_x();
        let p = Policy::lee_enhanced();
        assert_eq!(p.mode_for(8, &d), KernelMode::LargeBlock);
        assert_eq!(p.mode_for(100, &d), KernelMode::LargeBlock);
        assert!(p.launch_scale < 1.0 && p.compute_scale < 1.0);
    }
}
