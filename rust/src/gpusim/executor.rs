//! Level-ordered numeric factorization + timing: the simulated GPU solve.
//!
//! Executes the hybrid right-looking kernel level by level (real f64
//! arithmetic — results are validated against the sequential engines) while
//! the timing model of [`super::exec`] accounts cycles per level under the
//! chosen [`super::policy::Policy`].
//!
//! Note on floating point: on the real GPU, same-level columns may commit
//! MAC updates to a shared element in any order (atomics), so results are
//! reproducible only up to rounding. This simulator commits same-level
//! columns in ascending column order — one of the valid serializations.

use super::device::DeviceConfig;
use super::exec::{simulate_level, ColumnWork, LevelTiming};
use super::policy::Policy;
use crate::depend::Levels;
use crate::numeric::{LuFactors, PivotMonitor};
use crate::plan::FactorPlan;
use crate::symbolic::SymbolicFill;

/// Timing + structure report of a simulated factorization.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Policy label.
    pub policy: String,
    /// Total kernel cycles (levels + launches), excluding setup.
    pub kernel_cycles: u64,
    /// One-time driver/context setup cycles.
    pub setup_cycles: u64,
    /// Per-level detail.
    pub per_level: Vec<LevelTiming>,
    /// SM clock used for ms conversion.
    pub clock_ghz: f64,
}

impl SimReport {
    /// Kernel time in milliseconds (the paper's "numerical factorization
    /// time" column, which includes memory copy but not preprocessing).
    pub fn kernel_ms(&self) -> f64 {
        self.kernel_cycles as f64 / (self.clock_ghz * 1e6)
    }

    /// Total time including the one-time setup.
    pub fn total_ms(&self) -> f64 {
        (self.kernel_cycles + self.setup_cycles) as f64 / (self.clock_ghz * 1e6)
    }

    /// Count of levels by type (A, B, C) — Table III's distribution.
    pub fn level_distribution(&self) -> (usize, usize, usize) {
        let mut dist = (0, 0, 0);
        for l in &self.per_level {
            match l.mode.level_type() {
                'A' => dist.0 += 1,
                'B' => dist.1 += 1,
                _ => dist.2 += 1,
            }
        }
        dist
    }

    /// Mean warp occupancy weighted by level cycles.
    pub fn mean_occupancy(&self) -> f64 {
        let total: u64 = self.per_level.iter().map(|l| l.cycles).sum();
        if total == 0 {
            return 0.0;
        }
        self.per_level
            .iter()
            .map(|l| l.occupancy * l.cycles as f64)
            .sum::<f64>()
            / total as f64
    }
}

/// Run the simulated GPU factorization: numerics + cycle accounting.
///
/// `levels` must be a hazard-free schedule (from GLU2.0 or GLU3.0
/// dependency detection; [`crate::depend::levelize::validate_hazard_free`]
/// is the independent checker). Convenience wrapper over the plan-driven
/// core: builds a throwaway [`FactorPlan`] — hot paths
/// ([`crate::glu::GluSolver`]) build the plan once and call
/// [`simulate_refactorization`] directly.
pub fn simulate_factorization(
    sym: &SymbolicFill,
    levels: &Levels,
    policy: &Policy,
    device: &DeviceConfig,
) -> anyhow::Result<(LuFactors, SimReport)> {
    let plan = FactorPlan::from_levels(sym, levels.clone(), policy, device);
    let mut lu = sym.filled.clone();
    let mut lvals = Vec::new();
    let report =
        simulate_refactorization(&mut lu, &plan, &mut lvals, &mut PivotMonitor::new())?;
    Ok((LuFactors { lu }, report))
}

/// The in-place core of [`simulate_factorization`]: `lu` holds the filled
/// pattern with `A`'s values stamped in and is overwritten with the
/// factors while cycles are accounted per level. The executor no longer
/// decides anything — it *costs a given plan*: per-level modes, work
/// descriptions, and the subcolumn map all come from the [`FactorPlan`]
/// (built once per pattern and cached by the solver), `lvals` is the
/// reusable divide-phase scratch — the Newton-loop fast path reallocates
/// none of the `O(nnz)` state.
pub fn simulate_refactorization(
    lu: &mut crate::sparse::Csc,
    plan: &FactorPlan,
    lvals: &mut Vec<f64>,
    mon: &mut PivotMonitor,
) -> anyhow::Result<SimReport> {
    let n = lu.ncols();
    anyhow::ensure!(plan.n() == n, "plan dimension mismatch");
    let (policy, device) = (plan.policy(), plan.device());
    let urow = plan.urow();
    let col_work = plan.col_work();
    let mut per_level = Vec::with_capacity(plan.num_levels());
    let mut work: Vec<ColumnWork> = Vec::new();

    for (li, level) in plan.levels().levels.iter().enumerate() {
        // --- Timing: cost the level in the plan's mode. ---
        work.clear();
        work.extend(level.iter().map(|&j| col_work[j as usize]));
        // The modeled kernel consumes the pattern-time ScatterMap as its
        // gather/scatter index buffers (`indexed = true`): the cost model
        // credits the removed multiplier searches and row-match scans, so
        // the simulator stays reconciled with the indexed CPU twin
        // (`numeric::parrl::refactor_in_place`).
        let timing = simulate_level(
            &work,
            plan.level_plan(li).mode,
            n,
            device,
            policy.launch_scale_for(level.len()),
            policy.compute_scale,
            true,
        );
        per_level.push(timing);

        // --- Numerics: factor every column of the level (ascending), via
        // the column pipeline shared with `numeric::rightlook`. ---
        for &j in level {
            let j = j as usize;
            crate::numeric::rightlook::factor_column(lu, &urow[j], j, lvals, mon)?;
        }
    }

    Ok(SimReport {
        policy: policy.name.clone(),
        kernel_cycles: per_level.iter().map(|l| l.cycles).sum(),
        setup_cycles: device.setup_cycles,
        per_level,
        clock_ghz: device.clock_ghz,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::depend::{glu3, levelize};
    use crate::numeric::{leftlook, residual};
    use crate::sparse::gen;
    use crate::symbolic::symbolic_fill;
    use crate::util::Rng;

    fn setup(n: usize, seed: u64) -> (crate::sparse::Csc, SymbolicFill, Levels) {
        let a = gen::netlist(n, 6, 10, 0.08, 2, 0.2, seed);
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        (a, f, lv)
    }

    #[test]
    fn numerics_match_oracle() {
        let mut rng = Rng::new(0x5157);
        for trial in 0..10 {
            let n = rng.range(40, 200);
            let (a, f, lv) = setup(n, 4000 + trial);
            let d = DeviceConfig::titan_x();
            let (lu, _) =
                simulate_factorization(&f, &lv, &Policy::glu3(), &d).unwrap();
            let oracle = leftlook::factor(&f).unwrap();
            for (p, q) in lu.lu.values().iter().zip(oracle.lu.values()) {
                assert!(
                    (p - q).abs() < 1e-9 * (1.0 + q.abs()),
                    "trial {trial}: {p} vs {q}"
                );
            }
            let b = vec![1.0; a.nrows()];
            let x = lu.solve(&b);
            assert!(residual(&a, &x, &b) < 1e-10);
        }
    }

    #[test]
    fn all_policies_same_numerics_different_time() {
        let (_, f, lv) = setup(400, 9);
        let d = DeviceConfig::titan_x();
        let policies = [
            Policy::glu3(),
            Policy::glu2_fixed(),
            Policy::lee_enhanced(),
            Policy::glu3_no_small(),
            Policy::glu3_no_stream(),
        ];
        let mut results = Vec::new();
        for p in &policies {
            let (lu, rep) = simulate_factorization(&f, &lv, p, &d).unwrap();
            results.push((lu, rep));
        }
        let base = results[0].0.lu.values().to_vec();
        for (lu, rep) in &results {
            assert_eq!(lu.lu.values(), &base[..], "{}", rep.policy);
            assert!(rep.kernel_cycles > 0);
        }
    }

    #[test]
    fn report_accounting_consistent() {
        let (_, f, lv) = setup(300, 3);
        let d = DeviceConfig::titan_x();
        let (_, rep) = simulate_factorization(&f, &lv, &Policy::glu3(), &d).unwrap();
        assert_eq!(rep.per_level.len(), lv.num_levels());
        let (a, b, c) = rep.level_distribution();
        assert_eq!(a + b + c, lv.num_levels());
        assert!(rep.total_ms() > rep.kernel_ms());
        let occ = rep.mean_occupancy();
        assert!((0.0..=1.0).contains(&occ));
    }

    /// The simulated report's per-level mode histogram is exactly the
    /// plan's: the executor costs the plan, it never re-derives modes.
    #[test]
    fn report_distribution_matches_plan_histogram() {
        let (_, f, lv) = setup(350, 5);
        let d = DeviceConfig::titan_x();
        for policy in [Policy::glu3(), Policy::glu2_fixed(), Policy::glu3_no_stream()] {
            let plan = FactorPlan::from_levels(&f, lv.clone(), &policy, &d);
            let (_, rep) = simulate_factorization(&f, &lv, &policy, &d).unwrap();
            assert_eq!(rep.level_distribution(), plan.mode_histogram(), "{}", policy.name);
            for (timing, lp) in rep.per_level.iter().zip(plan.level_plans()) {
                assert_eq!(timing.mode, lp.mode);
                assert_eq!(timing.columns, lp.columns);
                assert_eq!(timing.max_subcols, lp.max_subcols);
            }
        }
    }

    #[test]
    fn glu3_not_slower_than_glu2_on_structured_matrix() {
        // An AMD-ordered mesh has the A/B/C level progression the adaptive
        // policy exploits; GLU3.0 should win (Table I's story). (Without a
        // fill-reducing ordering a grid levelizes to a sequential chain and
        // every policy is launch-bound.) Like the paper, the advantage only
        // materializes beyond a few thousand rows (rajat12's speedup in
        // Table I is just 1.1x) — use a 10k-node mesh.
        let g = gen::grid2d(100, 100, 7);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let d = DeviceConfig::titan_x();
        let (_, r3) = simulate_factorization(&f, &lv, &Policy::glu3(), &d).unwrap();
        let (_, r2) = simulate_factorization(&f, &lv, &Policy::glu2_fixed(), &d).unwrap();
        assert!(
            r3.kernel_cycles < r2.kernel_cycles,
            "GLU3.0 {} vs GLU2.0 {}",
            r3.kernel_cycles,
            r2.kernel_cycles
        );
        // And the ablations must straddle: full GLU3.0 is the fastest.
        let (_, rc2) = simulate_factorization(&f, &lv, &Policy::glu3_no_stream(), &d).unwrap();
        assert!(r3.kernel_cycles <= rc2.kernel_cycles);
    }

    #[test]
    fn small_matrices_near_parity() {
        // Paper Table I: rajat12 (n=1879) shows only 1.1x — on launch-bound
        // small matrices the policies are within ~15% of each other.
        let g = gen::grid2d(40, 40, 7);
        let p = crate::order::amd::amd_order(&g).unwrap();
        let a = g.permute(p.as_scatter(), p.as_scatter());
        let f = symbolic_fill(&a).unwrap();
        let lv = levelize(&glu3::detect(&f.filled));
        let d = DeviceConfig::titan_x();
        let (_, r3) = simulate_factorization(&f, &lv, &Policy::glu3(), &d).unwrap();
        let (_, r2) = simulate_factorization(&f, &lv, &Policy::glu2_fixed(), &d).unwrap();
        let ratio = r3.kernel_cycles as f64 / r2.kernel_cycles as f64;
        assert!((0.5..=1.15).contains(&ratio), "ratio {ratio}");
    }
}
