//! Fig. 9 — dependency graphs of the three detection methods on the
//! paper's 8×8 example matrix: GLU1.0 (incorrect), GLU2.0 (exact),
//! GLU3.0 (relaxed, superset). Prints the edge lists and the resulting
//! levelization, and checks the figure's claims.

use glu3::bench_support::paper_example;
use glu3::depend::{glu1, glu2, glu3 as g3, levelize, DepGraph};
use glu3::symbolic::symbolic_fill;

fn edges(g: &DepGraph) -> String {
    let mut s = String::new();
    for k in 0..g.n() {
        for &i in g.deps_of(k) {
            // paper uses 1-based labels and x -> y for "x depends on y"
            s.push_str(&format!("{} -> {}  ", k + 1, i + 1));
        }
    }
    s
}

fn main() {
    let a = paper_example();
    let sym = symbolic_fill(&a).expect("symbolic");
    let g1 = glu1::detect(&sym.filled);
    let g2 = glu2::detect(&sym.filled);
    let g3 = g3::detect(&sym.filled);

    println!("# Fig. 9 — dependency graphs on the example matrix (1-based labels)");
    println!("(a) GLU1.0 (U-pattern, incorrect) : {}", edges(&g1));
    println!("(b) GLU2.0 (exact double-U)       : {}", edges(&g2));
    println!("(c) GLU3.0 (relaxed)              : {}", edges(&g3));

    let l1 = levelize(&g1);
    let l2 = levelize(&g2);
    let l3 = levelize(&g3);
    println!(
        "levels: glu1 {} (unsafe), glu2 {}, glu3 {}",
        l1.num_levels(),
        l2.num_levels(),
        l3.num_levels()
    );

    // the figure's claims, enforced:
    assert!(g2.contains(&g1), "exact must contain U-pattern edges");
    assert!(
        g2.has_edge(5, 3),
        "the Fig. 4 double-U (6 -> 4, 1-based) must be detected"
    );
    assert!(g3.num_edges() >= g2.num_edges(), "relaxed is a superset");
    assert_eq!(
        l2.num_levels(),
        l3.num_levels(),
        "levelization identical despite redundant edges (paper claim)"
    );
    println!("fig9 OK: all Fig. 9 claims hold");
}
