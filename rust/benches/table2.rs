//! Table II — levelization runtimes: GLU2.0's O(n³) double-U search
//! (Algorithm 3) vs GLU3.0's relaxed detection (Algorithm 4), with the
//! resulting level counts and per-matrix speedups.
//!
//! This is the paper's headline "2–3 orders of magnitude" claim. Both
//! algorithms run on the same filled pattern; times are wall-clock of
//! detection + levelization.

use std::time::Instant;

use glu3::bench_support::bench_set;
use glu3::bench_support::table::{ms, Table};
use glu3::depend::{glu2, glu3 as g3, levelize};
use glu3::order::{preprocess, FillOrdering};
use glu3::sparse::gen;
use glu3::symbolic::symbolic_fill;
use glu3::util::stats::{arith_mean, geo_mean};

fn main() {
    let set = bench_set();
    let mut t = Table::new(vec![
        "matrix",
        "levels glu2",
        "levels glu3",
        "glu2 (ms)",
        "glu3 (ms)",
        "speed-up",
    ]);
    let mut ratios = Vec::new();

    for m in set {
        let a = gen::generate(&m.spec());
        let pre = preprocess(&a, FillOrdering::Amd, true).expect("preprocess");
        let sym = symbolic_fill(&pre.a).expect("symbolic");

        // Algorithm 3 verbatim — what GLU2.0 shipped and the paper timed
        // (this crate's optimized variant would understate the speedup;
        // see depend::glu2::detect_verbatim docs).
        let t2 = Instant::now();
        let d2 = glu2::detect_verbatim(&sym.filled);
        let l2 = levelize(&d2);
        let glu2_ms = t2.elapsed().as_secs_f64() * 1e3;

        let t3 = Instant::now();
        let d3 = g3::detect(&sym.filled);
        let l3 = levelize(&d3);
        let glu3_ms = t3.elapsed().as_secs_f64() * 1e3;

        let speedup = glu2_ms / glu3_ms;
        ratios.push(speedup);
        t.row(vec![
            m.ufl_name().to_string(),
            l2.num_levels().to_string(),
            l3.num_levels().to_string(),
            ms(glu2_ms),
            ms(glu3_ms),
            format!("{speedup:.1}"),
        ]);
        eprintln!("table2: {} done", m.ufl_name());
    }
    t.row(vec![
        "arith mean".into(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        format!("{:.1}", arith_mean(&ratios)),
    ]);
    t.row(vec![
        "geo mean".into(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        format!("{:.1}", geo_mean(&ratios)),
    ]);
    println!("# Table II — levelization runtimes (Alg. 3 vs Alg. 4)");
    print!("{}", t.render());
    println!("paper (full UFL suite): arith mean 8804.1, geo mean 3145.8; levels differ by at most a few");
}
