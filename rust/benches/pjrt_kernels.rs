//! L1/L2 kernel bench: the PJRT-executed Pallas kernels vs the native Rust
//! implementation of the same math — measures the AOT path's dispatch
//! overhead and throughput (EXPERIMENTS.md §Perf records these numbers).
//!
//! Skips (with a message) when `artifacts/` has not been built.

use glu3::bench_support::table::{ms, Table};
use glu3::runtime::{default_artifact_dir, Runtime};
use glu3::util::timer::measure;

fn main() {
    if !glu3::runtime::PJRT_ENABLED {
        println!("pjrt_kernels: built without the xla runtime feature — skipping");
        return;
    }
    let dir = default_artifact_dir();
    if !dir.join("quickstart.hlo.txt").exists() {
        println!("pjrt_kernels: artifacts not built (make artifacts) — skipping");
        return;
    }
    let rt = Runtime::load(&dir).expect("runtime load");
    println!("# PJRT kernel bench (artifacts: {:?})", rt.names());

    let mut t = Table::new(vec!["kernel", "shape", "pjrt (ms)", "native (ms)", "ratio"]);

    // level_update at both ladder sizes
    for (b, n) in glu3::runtime::LEVEL_SIZES {
        let x: Vec<f32> = (0..b * n).map(|i| (i % 13) as f32).collect();
        let u: Vec<f32> = (0..n).map(|i| (i % 7) as f32 * 0.5).collect();
        let s: Vec<f32> = (0..b).map(|i| (i % 3) as f32).collect();
        let pjrt = measure(3, 10, || rt.level_update(&x, &u, &s, b, n).unwrap());
        let native = measure(3, 10, || {
            let mut out = x.clone();
            for r in 0..b {
                let sr = s[r];
                for c in 0..n {
                    out[r * n + c] -= sr * u[c];
                }
            }
            out
        });
        t.row(vec![
            "level_update".to_string(),
            format!("{b}x{n}"),
            ms(pjrt.median_ms()),
            ms(native.median_ms()),
            format!("{:.1}", pjrt.median / native.median),
        ]);
    }

    // dense tail at both ladder sizes
    for tsize in glu3::runtime::TAIL_SIZES {
        let mut rng = glu3::util::Rng::new(tsize as u64);
        let mut a = vec![0f32; tsize * tsize];
        for r in 0..tsize {
            for c in 0..tsize {
                if r != c {
                    a[r * tsize + c] = rng.range_f64(-1.0, 1.0) as f32;
                }
            }
        }
        for d in 0..tsize {
            let sum: f32 = (0..tsize).filter(|&r| r != d).map(|r| a[r * tsize + d].abs()).sum();
            a[d * tsize + d] = sum + 1.0;
        }
        let rhs: Vec<f32> = (0..tsize).map(|i| (i % 5) as f32).collect();
        let a64: Vec<f64> = a.iter().map(|&v| v as f64).collect();
        let rhs64: Vec<f64> = rhs.iter().map(|&v| v as f64).collect();

        let pjrt = measure(2, 8, || rt.dense_tail_solve(&a, &rhs, tsize).unwrap());
        let native = measure(2, 8, || {
            glu3::numeric::dense::solve(&a64, tsize, &rhs64).unwrap()
        });
        t.row(vec![
            "dense_tail".to_string(),
            format!("{tsize}x{tsize}"),
            ms(pjrt.median_ms()),
            ms(native.median_ms()),
            format!("{:.1}", pjrt.median / native.median),
        ]);
    }
    print!("{}", t.render());
    println!("note: PJRT time includes buffer upload/download; the interpret-mode");
    println!("Pallas lowering is a CPU reference path (real-TPU perf is estimated");
    println!("in DESIGN.md §Perf from VMEM footprint + MXU utilization).");
}
