//! Table I — solver runtimes: GLU3.0 vs GLU2.0 (simulated GPU), enhanced
//! GLU2.0 [Lee], and the NICSLU-like CPU baseline; CPU preprocessing time;
//! per-matrix speedups plus arithmetic/geometric means.
//!
//! `GLU3_SET=small|med|all` selects the suite subset (see
//! `bench_support::bench_set`); EXPERIMENTS.md records the `all` run.
//!
//! Shape expectations vs the paper (absolute ms are not comparable — the
//! GPU is a timing simulator, the CPU baseline runs on this host):
//! speedup over GLU2.0 grows with matrix size, small matrices sit near 1x,
//! and the mean rows mirror the paper's 13.0x/6.7x (arith/geo) claim in
//! ordering, not magnitude.

use glu3::bench_support::table::{ms, ratio, Table};
use glu3::bench_support::bench_set;
use glu3::glu::{GluOptions, GluSolver, NumericEngine};
use glu3::gpusim::Policy;
use glu3::sparse::gen;
use glu3::util::stats::{arith_mean, geo_mean};

fn main() {
    let set = bench_set();
    let mut t = Table::new(vec![
        "matrix",
        "rows",
        "nz",
        "nnz",
        "cpu(ms)",
        "glu3(ms)",
        "glu2(ms)",
        "lee(ms)",
        "nicslu(ms)",
        "vs glu2",
        "vs lee",
        "vs nicslu",
    ]);
    let (mut s2, mut sl, mut sn) = (Vec::new(), Vec::new(), Vec::new());

    for m in set {
        let a = gen::generate(&m.spec());
        let run = |policy: Policy| -> (f64, f64) {
            let opts = GluOptions {
                policy,
                ..Default::default()
            };
            let s = GluSolver::factor(&a, &opts).expect("factor");
            (s.stats().numeric_ms, s.stats().cpu_ms())
        };
        let (glu3_ms, cpu_ms) = run(Policy::glu3());
        let (glu2_ms, _) = run(Policy::glu2_fixed());
        let (lee_ms, _) = run(Policy::lee_enhanced());

        // NICSLU-like CPU baseline: wall-clock of the multithreaded
        // left-looking engine (this host's core count).
        let nic_opts = GluOptions {
            engine: NumericEngine::ParallelCpu {
                threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
            },
            ..Default::default()
        };
        let nic = GluSolver::factor(&a, &nic_opts).expect("nicslu factor");
        let nic_ms = nic.stats().numeric_ms;

        let st = GluSolver::factor(&a, &GluOptions::default()).unwrap();
        let stats = st.stats();
        let r2 = glu2_ms / glu3_ms;
        let rl = lee_ms / glu3_ms;
        let rn = nic_ms / glu3_ms;
        s2.push(r2);
        sl.push(rl);
        sn.push(rn);
        t.row(vec![
            m.ufl_name().to_string(),
            stats.n.to_string(),
            stats.nz.to_string(),
            stats.nnz.to_string(),
            ms(cpu_ms),
            ms(glu3_ms),
            ms(glu2_ms),
            ms(lee_ms),
            ms(nic_ms),
            ratio(r2),
            ratio(rl),
            ratio(rn),
        ]);
        eprintln!("table1: {} done", m.ufl_name());
    }
    t.row(vec![
        "arith mean".into(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        ratio(arith_mean(&s2)),
        ratio(arith_mean(&sl)),
        ratio(arith_mean(&sn)),
    ]);
    t.row(vec![
        "geo mean".into(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        ratio(geo_mean(&s2)),
        ratio(geo_mean(&sl)),
        ratio(geo_mean(&sn)),
    ]);
    println!("# Table I — solver runtimes (simulated TITAN X; see DESIGN.md §2)");
    print!("{}", t.render());
    println!("paper (full UFL suite): vs GLU2.0 arith 13.0 / geo 6.7; vs [21] arith 7.1 / geo 4.8");
}
