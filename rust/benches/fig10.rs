//! Fig. 10 — number of parallelizable columns (level size) and maximum
//! subcolumns per level over the course of factorization, for the
//! ASIC_100ks-class matrix. Prints the two series (the paper's subfigures
//! (a)/(b)) plus the type A/B/C segmentation and the inverse-correlation
//! statistic that motivates Eq. 4.

use glu3::bench_support::table::Table;
use glu3::glu::profile::{parallelism_profile, size_subcol_correlation};
use glu3::glu::{GluOptions, GluSolver};
use glu3::gpusim::{DeviceConfig, KernelMode};
use glu3::sparse::gen::{self, SuiteMatrix};

fn main() {
    let m = SuiteMatrix::Asic100ks;
    let a = gen::generate(&m.spec());
    let s = GluSolver::factor(&a, &GluOptions::default()).expect("factor");
    let prof = parallelism_profile(s.symbolic(), s.levels());
    let dev = DeviceConfig::titan_x();

    println!(
        "# Fig. 10 — parallelism profile of {} ({} levels)",
        m.ufl_name(),
        prof.len()
    );
    let mut t = Table::new(vec!["level", "size", "max_subcols", "type"]);
    // Print a readable subsample: every level for the first 20, then 1-in-k.
    let stride = (prof.len() / 60).max(1);
    for (i, p) in prof.iter().enumerate() {
        if i > 20 && i % stride != 0 && i != prof.len() - 1 {
            continue;
        }
        let mode = glu3::gpusim::exec::select_mode(p.size, 16, &dev);
        t.row(vec![
            p.level.to_string(),
            p.size.to_string(),
            p.max_subcols.to_string(),
            mode.level_type().to_string(),
        ]);
    }
    print!("{}", t.render());

    let (mut na, mut nb, mut nc) = (0, 0, 0);
    for p in &prof {
        match glu3::gpusim::exec::select_mode(p.size, 16, &dev) {
            KernelMode::SmallBlock { .. } => na += 1,
            KernelMode::LargeBlock => nb += 1,
            KernelMode::Stream => nc += 1,
        }
    }
    let corr = size_subcol_correlation(&prof);
    println!("type distribution: A={na} B={nb} C={nc}");
    println!("size vs max-subcols correlation: {corr:.3} (paper: inverse)");
    assert!(corr < 0.1, "Fig. 10's inverse correlation must hold");
    assert!(prof[0].size > prof.last().unwrap().size, "sizes must shrink");
    println!("fig10 OK");
}
