//! Table III — mode ablations: GPU kernel time with all three modes
//! (GLU3.0) vs case 1 (small-block disabled) vs case 2 (stream disabled),
//! plus the A/B/C level-type distribution.
//!
//! Shape expectations: case 1 hurts most matrices moderately (type A levels
//! are few but cheap to win), case 2 hurts type-C-heavy matrices badly, and
//! very large-n matrices can *gain* from disabling small-block mode (the
//! Eq. 5 column-cache cap — the paper's G3_circuit anomaly).

use glu3::bench_support::bench_set;
use glu3::bench_support::table::{ms, Table};
use glu3::glu::{GluOptions, GluSolver};
use glu3::gpusim::Policy;
use glu3::sparse::gen;

fn main() {
    let set = bench_set();
    let mut t = Table::new(vec![
        "matrix",
        "GLU3.0(ms)",
        "case1(ms)",
        "case2(ms)",
        "A",
        "B",
        "C",
    ]);
    for m in set {
        let a = gen::generate(&m.spec());
        let run = |policy: Policy| -> (f64, (usize, usize, usize)) {
            let opts = GluOptions {
                policy,
                ..Default::default()
            };
            let s = GluSolver::factor(&a, &opts).expect("factor");
            let stats = s.stats();
            let dist = stats.sim.as_ref().map(|r| r.level_distribution()).unwrap_or((0, 0, 0));
            (stats.numeric_ms, dist)
        };
        let (full, dist) = run(Policy::glu3());
        let (case1, _) = run(Policy::glu3_no_small());
        let (case2, _) = run(Policy::glu3_no_stream());
        t.row(vec![
            m.ufl_name().to_string(),
            ms(full),
            ms(case1),
            ms(case2),
            dist.0.to_string(),
            dist.1.to_string(),
            dist.2.to_string(),
        ]);
        eprintln!("table3: {} done", m.ufl_name());
    }
    println!("# Table III — kernel-mode ablations (case 1: no small block; case 2: no stream)");
    print!("{}", t.render());
    println!("paper: stream mode (case 2 delta) dominates; G3_circuit is faster in case 1 (Eq. 5 cap)");
}
