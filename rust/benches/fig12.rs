//! Fig. 12 — GPU kernel runtime vs the stream-mode threshold N, normalized
//! to N = 5, over the stream-heavy matrices (the paper plots the ones that
//! benefit most from stream mode). The paper's finding: runtime keeps
//! dropping until N = 16, and N > 16 is flat or worse — which is why
//! GLU3.0 fixes the threshold at 16.

use glu3::bench_support::table::Table;
use glu3::glu::{GluOptions, GluSolver};
use glu3::gpusim::Policy;
use glu3::sparse::gen::{self, SuiteMatrix};

const THRESHOLDS: [usize; 6] = [5, 8, 12, 16, 24, 32];

fn main() {
    // Stream-heavy subset (matches the paper's selection criterion).
    let matrices = [
        SuiteMatrix::Onetone2,
        SuiteMatrix::Rajat15,
        SuiteMatrix::Rajat27,
        SuiteMatrix::Rajat26,
    ];
    let mut header: Vec<String> = vec!["matrix".into()];
    header.extend(THRESHOLDS.iter().map(|n| format!("N={n}")));
    let mut t = Table::new(header);

    let mut n16_wins = 0usize;
    for m in matrices {
        let a = gen::generate(&m.spec());
        let mut times = Vec::new();
        for &n in &THRESHOLDS {
            let opts = GluOptions {
                policy: Policy::glu3_with_threshold(n),
                ..Default::default()
            };
            let s = GluSolver::factor(&a, &opts).expect("factor");
            times.push(s.stats().numeric_ms);
        }
        let base = times[0];
        let mut row = vec![m.ufl_name().to_string()];
        row.extend(times.iter().map(|t| format!("{:.3}", t / base)));
        t.row(row);
        // check the paper's shape: N=16 no slower than N=5 and N=8
        let i16 = THRESHOLDS.iter().position(|&n| n == 16).unwrap();
        if times[i16] <= times[0] * 1.001 {
            n16_wins += 1;
        }
        eprintln!("fig12: {} done", m.ufl_name());
    }
    println!("# Fig. 12 — kernel runtime vs stream threshold N (normalized to N=5)");
    print!("{}", t.render());
    println!("paper: runtime keeps reducing until N=16; larger N flat or slower");
    // Shape note: the paper's curves drop 5-20% toward N=16 and flatten.
    // Under this simulator the sweep is flat to slightly rising (<= ~7%):
    // our per-column stream-launch tail outweighs the compute gain on
    // 5-16-column levels of the (sparser) synthetic suite. Both agree on
    // the flat tail beyond 16; the location of the shallow optimum is the
    // one shape this model does not pin down (EXPERIMENTS.md discusses).
    if n16_wins >= matrices.len() - 1 {
        println!("fig12 OK ({n16_wins}/{} matrices favor N=16 over N=5)", matrices.len());
    } else {
        println!(
            "fig12 NOTE: {n16_wins}/{} matrices favor N=16 over N=5 on this \
             simulator; sweep is flat within a few percent (see EXPERIMENTS.md)",
            matrices.len()
        );
    }
}
