"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated against these references by
``python/tests`` (pytest + hypothesis). The references are deliberately
written in the most obvious jnp form — no tiling, no tricks — so a mismatch
always indicts the kernel.
"""

import jax.numpy as jnp
from jax import lax


def ref_level_update(x, u, s):
    """Batched subcolumn MAC update (paper Eq. 3).

    ``x``: (B, N) gathered subcolumn targets, one row per subcolumn;
    ``u``: (N,) the pivot column's L entries (dense-gathered);
    ``s``: (B,) the multipliers ``As(j, k)`` per subcolumn.

    Returns ``x - s[:, None] * u[None, :]`` — one rank-1 MAC.
    """
    return x - s[:, None] * u[None, :]


def ref_dense_lu(a):
    """Dense LU without pivoting, compact storage (unit L implicit).

    Equivalent to ``rust/src/numeric/dense.rs::lu_nopivot_inplace``.
    """
    n = a.shape[0]
    rows = jnp.arange(n)

    def step(k, a):
        pivot = a[k, k]
        m = jnp.where(rows > k, a[:, k] / pivot, 0.0)
        urow = jnp.where(rows > k, a[k, :], 0.0)
        a = a - m[:, None] * urow[None, :]
        a = a.at[:, k].set(jnp.where(rows > k, m, a[:, k]))
        return a

    return lax.fori_loop(0, n, step, a)


def ref_lower_unit_solve(lu, b):
    """Forward substitution with the unit-lower factor of compact ``lu``."""
    n = lu.shape[0]
    rows = jnp.arange(n)

    def step(j, x):
        lcol = jnp.where(rows > j, lu[:, j], 0.0)
        return x - lcol * x[j]

    return lax.fori_loop(0, n, step, b)


def ref_upper_solve(lu, b):
    """Backward substitution with the upper factor of compact ``lu``."""
    n = lu.shape[0]
    rows = jnp.arange(n)

    def step(i, x):
        j = n - 1 - i
        xj = x[j] / lu[j, j]
        x = x.at[j].set(xj)
        ucol = jnp.where(rows < j, lu[:, j], 0.0)
        return x - ucol * xj

    return lax.fori_loop(0, n, step, b)


def ref_dense_solve(a, b):
    """Full dense solve through the compact-LU path (factor + 2 solves)."""
    lu = ref_dense_lu(a)
    y = ref_lower_unit_solve(lu, b)
    return ref_upper_solve(lu, y)
