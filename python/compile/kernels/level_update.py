"""L1 Pallas kernel: the batched subcolumn MAC update (paper Eq. 2/3).

The GLU submatrix update for one pivot column ``j`` is a masked rank-1
update (Eq. 2). The Rust coordinator gathers the level's subcolumn targets
into a dense ``(B, N)`` buffer (one row per subcolumn, padded), the pivot
column's L entries into ``u (N,)``, and the per-subcolumn multipliers into
``s (B,)``; this kernel then computes ``X -= s ⊗ u`` tile by tile.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper parallelizes this
with one CUDA warp (or block) per subcolumn; here BlockSpec tiles of
``(TB, TN)`` express the HBM↔VMEM schedule instead — the grid dimension
over B is the analogue of the warp/block-per-subcolumn axis, the N tiling
replaces the per-warp strided loop. Elementwise MAC ⇒ VPU-bound; tiles are
sized to keep the working set ≤ ~0.5 MiB of VMEM per program.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md); numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM tile: 128 x 512 f32 = 256 KiB (fits comfortably with double
# buffering in 16 MiB VMEM per core).
TILE_B = 128
TILE_N = 512


def _kernel(x_ref, u_ref, s_ref, o_ref):
    # One (TB, TN) tile: o = x - s ⊗ u.
    o_ref[...] = x_ref[...] - s_ref[...][:, None] * u_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("tile_b", "tile_n"))
def level_update(x, u, s, *, tile_b=TILE_B, tile_n=TILE_N):
    """``x - s[:, None] * u[None, :]`` via a tiled Pallas kernel.

    ``x``: (B, N); ``u``: (N,); ``s``: (B,). B and N need not be multiples
    of the tile sizes (Pallas pads the edge programs).
    """
    b, n = x.shape
    tb = min(tile_b, b)
    tn = min(tile_n, n)
    grid = (pl.cdiv(b, tb), pl.cdiv(n, tn))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tn,), lambda i, j: (j,)),
            pl.BlockSpec((tb,), lambda i, j: (i,)),
        ],
        out_specs=pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x, u, s)


def vmem_bytes(tile_b=TILE_B, tile_n=TILE_N, dtype_bytes=4):
    """Estimated VMEM working set per program (x tile in+out, u, s)."""
    return dtype_bytes * (2 * tile_b * tile_n + tile_n + tile_b)
