"""L1 Pallas kernel: dense triangular solves over a compact LU tile.

Completes the dense-tail path: after ``dense_lu`` factors the trailing
block, these kernels run the forward (unit-lower) and backward (upper)
substitutions. Single-program kernels with `fori_loop` + masking, same
VMEM-resident regime as ``dense_lu``.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _lower_kernel(lu_ref, b_ref, o_ref):
    lu = lu_ref[...]
    x = b_ref[...]
    n = lu.shape[0]
    rows = lax.broadcasted_iota(jnp.int32, (n,), 0)

    def step(j, x):
        lcol = jnp.where(rows > j, lu[:, j], 0.0)
        return x - lcol * x[j]

    o_ref[...] = lax.fori_loop(0, n, step, x)


def _upper_kernel(lu_ref, b_ref, o_ref):
    lu = lu_ref[...]
    x = b_ref[...]
    n = lu.shape[0]
    rows = lax.broadcasted_iota(jnp.int32, (n,), 0)

    def step(i, x):
        j = n - 1 - i
        xj = x[j] / lu[j, j]
        x = x.at[j].set(xj)
        ucol = jnp.where(rows < j, lu[:, j], 0.0)
        return x - ucol * xj

    o_ref[...] = lax.fori_loop(0, n, step, x)


@jax.jit
def lower_unit_solve(lu, b):
    """Solve ``L x = b`` with the unit-lower factor of compact ``lu``."""
    n = lu.shape[0]
    return pl.pallas_call(
        _lower_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        interpret=True,
    )(lu, b)


@jax.jit
def upper_solve(lu, b):
    """Solve ``U x = b`` with the upper factor of compact ``lu``."""
    n = lu.shape[0]
    return pl.pallas_call(
        _upper_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), b.dtype),
        interpret=True,
    )(lu, b)
