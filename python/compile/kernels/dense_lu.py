"""L1 Pallas kernel: blocked dense LU (no pivoting) for the dense tail.

Sparse circuit factorizations end in a (nearly) dense trailing submatrix —
the type-C levels where every column touches every later column. GLU keeps
grinding through them with sparse subcolumn updates; a classic alternative
(SuperLU-style) is to switch to a dense kernel once the tail densifies.
This kernel is that dense tail on the TPU mapping: a right-looking panel
LU whose trailing Schur update is an (n-k)×(n-k)×1 outer product per step —
the MXU-friendly part that dominates the FLOPs for T ≥ 128.

Single-program kernel (grid=()): the whole T×T tile lives in VMEM
(T ≤ 512 ⇒ ≤ 1 MiB f32), and `lax.fori_loop` walks the pivots with masked
updates — the Pallas analogue of the paper's in-kernel column loop.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _kernel(a_ref, o_ref):
    a = a_ref[...]
    n = a.shape[0]
    rows = lax.broadcasted_iota(jnp.int32, (n,), 0)

    def step(k, a):
        pivot = a[k, k]
        m = jnp.where(rows > k, a[:, k] / pivot, 0.0)
        urow = jnp.where(rows > k, a[k, :], 0.0)
        a = a - m[:, None] * urow[None, :]
        a = a.at[:, k].set(jnp.where(rows > k, m, a[:, k]))
        return a

    o_ref[...] = lax.fori_loop(0, n, step, a)


@jax.jit
def dense_lu(a):
    """Compact LU (unit-L implicit) of a dense square tile, no pivoting."""
    n = a.shape[0]
    assert a.shape == (n, n)
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,
    )(a)


@functools.partial(jax.jit, static_argnames=())
def dense_lu_batched(a):
    """vmapped dense LU over a batch of tiles (B, T, T)."""
    return jax.vmap(dense_lu)(a)


def flops(t):
    """~(2/3)T³ MACs; the share in rank-k Schur updates (MXU-eligible)
    approaches 100% as T grows — reported in DESIGN.md §Perf."""
    return 2 * t**3 // 3
