"""AOT lowering: JAX graphs -> HLO text artifacts for the Rust runtime.

HLO *text* (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that xla_extension
0.5.1 (what the published xla 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage::

    python -m compile.aot --outdir ../artifacts

Incremental: a manifest of source hashes makes re-runs no-ops when nothing
changed (the Makefile relies on this).

Artifact ladder (static shapes; the Rust side pads into the next size up):

- ``level_update_{B}x{N}``   B in {64, 256}, N in {256, 2048}
- ``dense_tail_{T}``         T in {64, 256}: LU factor + solve, one RHS
- ``quickstart``             2x2 matmul smoke graph
"""

import argparse
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (name, builder, example-arg factory)
LEVEL_SIZES = [(64, 256), (256, 2048)]
TAIL_SIZES = [64, 256]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifacts():
    """Yield (name, lowered) for every artifact in the ladder."""
    f32 = jnp.float32
    for b, n in LEVEL_SIZES:
        spec_x = jax.ShapeDtypeStruct((b, n), f32)
        spec_u = jax.ShapeDtypeStruct((n,), f32)
        spec_s = jax.ShapeDtypeStruct((b,), f32)
        yield (
            f"level_update_{b}x{n}",
            jax.jit(model.level_update_graph).lower(spec_x, spec_u, spec_s),
        )
    for t in TAIL_SIZES:
        spec_a = jax.ShapeDtypeStruct((t, t), f32)
        spec_b = jax.ShapeDtypeStruct((t,), f32)
        yield (
            f"dense_tail_{t}",
            jax.jit(model.dense_tail_solve_graph).lower(spec_a, spec_b),
        )
    spec2 = jax.ShapeDtypeStruct((2, 2), f32)
    yield ("quickstart", jax.jit(model.quickstart_graph).lower(spec2, spec2))


def source_digest() -> str:
    """Hash of every .py under compile/ — the staleness key."""
    root = pathlib.Path(__file__).parent
    h = hashlib.sha256()
    for p in sorted(root.rglob("*.py")):
        h.update(p.read_bytes())
    return h.hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    outdir = pathlib.Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)
    manifest_path = outdir / "manifest.json"
    digest = source_digest()

    if not args.force and manifest_path.exists():
        try:
            manifest = json.loads(manifest_path.read_text())
            if manifest.get("digest") == digest and all(
                (outdir / f"{name}.hlo.txt").exists() for name in manifest.get("names", [])
            ):
                print(f"artifacts up to date in {outdir} (digest {digest[:12]})")
                return 0
        except (json.JSONDecodeError, OSError):
            pass

    names = []
    for name, lowered in artifacts():
        text = to_hlo_text(lowered)
        path = outdir / f"{name}.hlo.txt"
        path.write_text(text)
        names.append(name)
        print(f"wrote {path} ({len(text)} chars)")

    manifest_path.write_text(json.dumps({"digest": digest, "names": names}, indent=1))
    print(f"manifest: {len(names)} artifacts, digest {digest[:12]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
