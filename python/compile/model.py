"""L2: the JAX compute graphs the Rust coordinator calls through PJRT.

Two graphs, both built on the L1 Pallas kernels:

- :func:`level_update_graph` — the paper's submatrix update (Eq. 2/3) over a
  gathered dense batch: the per-level numeric workhorse.
- :func:`dense_tail_solve_graph` — factor the trailing dense block and solve
  it against one RHS: the dense-tail alternative the ablation benches
  compare against pure-sparse grinding.

Each graph is lowered once by :mod:`compile.aot` to HLO *text* (the
interchange the xla 0.1.6 crate can parse — see /opt/xla-example/README.md)
and executed from ``rust/src/runtime/`` at request time. Python never runs
on the request path.
"""

import jax.numpy as jnp

from .kernels.dense_lu import dense_lu
from .kernels.level_update import level_update
from .kernels.trisolve import lower_unit_solve, upper_solve


def level_update_graph(x, u, s):
    """(B, N), (N,), (B,) -> (B, N): the Eq. 3 batched MAC."""
    return (level_update(x, u, s),)


def dense_tail_solve_graph(a, b):
    """(T, T), (T,) -> (lu, x): factor the tail tile and solve one RHS."""
    lu = dense_lu(a)
    y = lower_unit_solve(lu, b)
    x = upper_solve(lu, y)
    return (lu, x)


def dense_tail_factor_graph(a):
    """(T, T) -> (T, T) compact LU of the tail tile."""
    return (dense_lu(a),)


def quickstart_graph(x, y):
    """Tiny smoke graph used by the runtime's unit tests: matmul + 2."""
    return (jnp.matmul(x, y) + 2.0,)
