"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; every property compares against
``compile.kernels.ref`` with ``assert_allclose`` — the core correctness
signal of the Python layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dense_lu import dense_lu, dense_lu_batched, flops
from compile.kernels.level_update import level_update, vmem_bytes
from compile.kernels.trisolve import lower_unit_solve, upper_solve

jax.config.update("jax_enable_x64", True)


def rand(rng, shape, dtype):
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape), dtype=dtype)


def tol(dtype):
    """XLA may fuse multiply-add differently between the jitted kernel and
    the eager reference (FMA contraction), so comparisons are to a few ulps
    rather than bit-exact."""
    return 1e-6 if dtype == jnp.float32 else 1e-14


def dd_matrix(rng, n, dtype):
    """Column diagonally dominant matrix (no-pivot LU well-defined)."""
    a = rng.uniform(-1.0, 1.0, size=(n, n))
    np.fill_diagonal(a, np.abs(a).sum(axis=0) + 1.0)
    return jnp.asarray(a, dtype=dtype)


# ---------------------------------------------------------------- level_update

@settings(max_examples=40, deadline=None)
@given(
    b=st.integers(1, 130),
    n=st.integers(1, 600),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_level_update_matches_ref(b, n, dtype, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, (b, n), dtype)
    u = rand(rng, (n,), dtype)
    s = rand(rng, (b,), dtype)
    got = level_update(x, u, s)
    want = ref.ref_level_update(x, u, s)
    np.testing.assert_allclose(got, want, rtol=tol(dtype), atol=tol(dtype))


@pytest.mark.parametrize("tile", [(8, 16), (128, 512), (4, 600)])
def test_level_update_tile_invariance(tile):
    rng = np.random.default_rng(7)
    x = rand(rng, (37, 211), jnp.float32)
    u = rand(rng, (211,), jnp.float32)
    s = rand(rng, (37,), jnp.float32)
    got = level_update(x, u, s, tile_b=tile[0], tile_n=tile[1])
    np.testing.assert_allclose(got, ref.ref_level_update(x, u, s),
                               rtol=1e-6, atol=1e-6)


def test_level_update_vmem_budget():
    # default tiles must fit VMEM with double buffering (~16 MiB/core)
    assert vmem_bytes() * 2 < 16 << 20


# ---------------------------------------------------------------- dense_lu

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 96),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_lu_matches_ref(n, dtype, seed):
    rng = np.random.default_rng(seed)
    a = dd_matrix(rng, n, dtype)
    got = dense_lu(a)
    want = ref.ref_dense_lu(a)
    t = 1e-4 if dtype == jnp.float32 else 1e-12  # n-step accumulation
    np.testing.assert_allclose(got, want, rtol=t, atol=t)


def test_dense_lu_reconstructs_a():
    rng = np.random.default_rng(3)
    n = 48
    a = dd_matrix(rng, n, jnp.float64)
    lu = np.asarray(dense_lu(a))
    l = np.tril(lu, -1) + np.eye(n)
    u = np.triu(lu)
    np.testing.assert_allclose(l @ u, np.asarray(a), rtol=1e-12, atol=1e-12)


def test_dense_lu_batched_matches_loop():
    rng = np.random.default_rng(5)
    batch = jnp.stack([dd_matrix(rng, 16, jnp.float64) for _ in range(6)])
    got = dense_lu_batched(batch)
    for i in range(6):
        np.testing.assert_allclose(got[i], dense_lu(batch[i]), rtol=1e-14, atol=1e-14)


def test_flops_model():
    assert flops(256) == 2 * 256**3 // 3


# ---------------------------------------------------------------- trisolve

@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 80), seed=st.integers(0, 2**31 - 1))
def test_trisolve_round_trip(n, seed):
    rng = np.random.default_rng(seed)
    a = dd_matrix(rng, n, jnp.float64)
    b = rand(rng, (n,), jnp.float64)
    lu = dense_lu(a)
    y = lower_unit_solve(lu, b)
    x = upper_solve(lu, y)
    # A x == b
    np.testing.assert_allclose(np.asarray(a) @ np.asarray(x), np.asarray(b),
                               rtol=1e-9, atol=1e-9)
    # and each half matches its oracle exactly
    np.testing.assert_allclose(y, ref.ref_lower_unit_solve(lu, b), rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(x, ref.ref_upper_solve(lu, y), rtol=1e-12, atol=1e-12)


def test_dense_solve_vs_jnp_linalg():
    rng = np.random.default_rng(11)
    n = 40
    a = dd_matrix(rng, n, jnp.float64)
    b = rand(rng, (n,), jnp.float64)
    x = ref.ref_dense_solve(a, b)
    want = jnp.linalg.solve(a, b)
    np.testing.assert_allclose(x, want, rtol=1e-9, atol=1e-9)
