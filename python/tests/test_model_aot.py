"""L2 + AOT: composed graphs and HLO-text lowering."""

import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def test_dense_tail_solve_graph_solves():
    rng = np.random.default_rng(2)
    n = 32
    a = rng.uniform(-1, 1, (n, n))
    np.fill_diagonal(a, np.abs(a).sum(axis=0) + 1.0)
    a = jnp.asarray(a)
    b = jnp.asarray(rng.uniform(-1, 1, n))
    lu, x = model.dense_tail_solve_graph(a, b)
    np.testing.assert_allclose(np.asarray(a) @ np.asarray(x), np.asarray(b),
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_allclose(lu, ref.ref_dense_lu(a), rtol=1e-12, atol=1e-12)


def test_level_update_graph_tuple():
    x = jnp.ones((4, 8), jnp.float32)
    u = jnp.ones((8,), jnp.float32)
    s = 2.0 * jnp.ones((4,), jnp.float32)
    (out,) = model.level_update_graph(x, u, s)
    np.testing.assert_allclose(out, -jnp.ones((4, 8)), rtol=1e-6, atol=1e-6)


def test_hlo_text_lowering_all_artifacts():
    """Every artifact lowers to parseable-looking HLO text.

    Lowered with x64 *disabled* — exactly how ``python -m compile.aot``
    runs (this test module enables x64 globally for oracle precision).
    """
    jax.config.update("jax_enable_x64", False)
    try:
        for name, lowered in aot.artifacts():
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name
            # f32 graphs only — the rust runtime feeds f32 buffers.
            assert "f64" not in text, f"{name} must lower in f32"
    finally:
        jax.config.update("jax_enable_x64", True)


def test_aot_cli_incremental(tmp_path):
    """Second run with unchanged sources is a no-op."""
    env_dir = pathlib.Path(__file__).resolve().parents[1]
    out = tmp_path / "artifacts"
    cmd = [sys.executable, "-m", "compile.aot", "--outdir", str(out)]
    r1 = subprocess.run(cmd, cwd=env_dir, capture_output=True, text=True)
    assert r1.returncode == 0, r1.stderr
    assert (out / "manifest.json").exists()
    assert (out / "quickstart.hlo.txt").exists()
    r2 = subprocess.run(cmd, cwd=env_dir, capture_output=True, text=True)
    assert r2.returncode == 0, r2.stderr
    assert "up to date" in r2.stdout
