//! Offline vendored stand-in for the `anyhow` crate.
//!
//! The build environment has no network access and no crates.io registry, so
//! this workspace carries a minimal, dependency-free reimplementation of the
//! `anyhow` API subset the `glu3` crate actually uses:
//!
//! - [`Error`] / [`Result`] — a string-chain error type (context frames are
//!   flattened to strings eagerly) with an optional typed payload for
//!   [`Error::downcast_ref`].
//! - [`anyhow!`], [`bail!`], [`ensure!`] — the formatting macros.
//! - [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//!
//! Display mirrors `anyhow`: `{}` prints the outermost message only, `{:#}`
//! prints the whole chain separated by `": "`, and `{:?}` prints the
//! outermost message followed by a `Caused by:` list.

use std::any::Any;
use std::error::Error as StdError;
use std::fmt;

/// A flattened error chain. `chain[0]` is the outermost (most recent
/// context) message; later entries are the causes, outermost-in first.
/// `payload` optionally carries the original typed value so callers can
/// recover structured error information with [`Error::downcast_ref`] —
/// the subset of real `anyhow`'s downcasting this workspace needs.
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error {
            chain: vec![message.to_string()],
            payload: None,
        }
    }

    /// Wrap a standard error, capturing its `source()` chain.
    pub fn new<E: StdError>(error: E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error {
            chain,
            payload: None,
        }
    }

    /// Create an error whose Display is `message` and whose typed payload is
    /// `value` — recoverable later through [`Error::downcast_ref`]. Context
    /// frames stacked on top preserve the payload.
    pub fn with_payload<T: Any + Send + Sync>(message: impl fmt::Display, value: T) -> Self {
        Error {
            chain: vec![message.to_string()],
            payload: Some(Box::new(value)),
        }
    }

    /// Borrow the typed payload, if one of type `T` was attached at
    /// construction. Context frames do not erase it.
    pub fn downcast_ref<T: Any>(&self) -> Option<&T> {
        self.payload.as_deref().and_then(|p| p.downcast_ref())
    }

    /// Push a new outermost context frame (the payload is preserved).
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The chain of messages, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`: that keeps
// this blanket conversion coherent (the same trick real `anyhow` uses).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (or any `Display` value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

/// Attach context to a `Result` or `Option` error path.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let v: usize = s.parse()?; // From<ParseIntError> via the blanket impl
        Ok(v)
    }

    #[test]
    fn question_mark_on_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("bad thing {}", 7);
        assert_eq!(format!("{e}"), "bad thing 7");

        fn f(x: i32) -> Result<()> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(())
        }
        assert!(f(5).is_ok());
        assert_eq!(format!("{}", f(-1).unwrap_err()), "x must be positive, got -1");
        assert_eq!(format!("{}", f(101).unwrap_err()), "too big");
    }

    #[test]
    fn payload_survives_context_frames() {
        #[derive(Debug, PartialEq)]
        struct Marker(usize);

        let e = Error::with_payload("bad column 3", Marker(3));
        assert_eq!(format!("{e}"), "bad column 3");
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(3)));
        assert!(e.downcast_ref::<String>().is_none());

        let e = e.context("while refactoring");
        assert_eq!(format!("{e}"), "while refactoring");
        assert_eq!(e.downcast_ref::<Marker>(), Some(&Marker(3)));

        // plain errors carry no payload
        assert!(anyhow!("plain").downcast_ref::<Marker>().is_none());
    }

    #[test]
    fn context_chains_render() {
        let r: Result<()> = Err(anyhow!("root"));
        let e = r.map_err(|e| e.context("outer")).unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root");
        assert!(format!("{e:?}").contains("Caused by:"));

        let none: Option<u32> = None;
        let e = none.context("missing value").unwrap_err();
        assert_eq!(format!("{e}"), "missing value");

        let io: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "disk on fire",
        ));
        let e = io.with_context(|| format!("writing {}", "x")).unwrap_err();
        assert_eq!(format!("{e:#}"), "writing x: disk on fire");
        assert_eq!(e.root_cause(), "disk on fire");
        assert_eq!(e.chain().count(), 2);
    }
}
