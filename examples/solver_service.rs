//! The L3 coordinator as a service: a [`SolverPool`] serving concurrent
//! solve requests from 4 client threads over a mixed-pattern workload —
//! the "serving" view of the solver (vLLM-router flavor, scaled to a
//! linear-algebra service).
//!
//! Each client thread repeatedly restamps one of three circuit matrices
//! with fresh values (the Newton–Raphson access pattern) and submits a
//! batched multi-RHS solve. Only the warm-up request per *pattern* pays the
//! symbolic pipeline (MC64 + AMD + fill + dependency detection +
//! levelization); every threaded request hits the pattern cache and takes
//! the numeric-only refactor fast path, so the symbolic-cache hit rate on
//! this workload is ≥ 90% by construction (3 warm-up misses, then 100
//! hits). The serial warm-up also keeps the number deterministic: cold
//! patterns hit by several threads at once can otherwise each be factored
//! more than once, since the pool deliberately factors outside its shard
//! locks.
//!
//! ```text
//! cargo run --release --example solver_service
//! ```

use std::time::Instant;

use glu3::coordinator::SolverPool;
use glu3::glu::{amortization_profile, GluOptions};
use glu3::numeric::residual;
use glu3::sparse::gen::{self, restamp_columns, SuiteMatrix};
use glu3::sparse::Csc;
use glu3::util::Rng;

const THREADS: usize = 4;
const REQUESTS_PER_THREAD: usize = 25;
const RHS_PER_REQUEST: usize = 4;

fn main() -> anyhow::Result<()> {
    // Three distinct sparsity patterns (three circuits being simulated).
    let patterns: Vec<(&str, Csc)> = [
        SuiteMatrix::Rajat12,
        SuiteMatrix::Circuit2,
        SuiteMatrix::Memplus,
    ]
    .into_iter()
    .map(|m| (m.ufl_name(), gen::generate(&m.spec())))
    .collect();
    for (name, a) in &patterns {
        println!("pattern {:10} n={:6} nz={}", name, a.nrows(), a.nnz());
    }

    let pool = SolverPool::new(GluOptions::default());

    // Serial warm-up: factor each pattern once so the threaded phase is
    // all hits (and the hit-rate below is deterministic).
    let mut warm_rng = Rng::new(0xAA);
    for (_, base) in &patterns {
        let m = restamp_columns(base, &mut warm_rng);
        let b = vec![1.0; m.nrows()];
        pool.solve(&m, &b)?;
    }

    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for t in 0..THREADS {
            let pool = &pool;
            let patterns = &patterns;
            scope.spawn(move || {
                let mut rng = Rng::new(0xC11E57 + t as u64);
                for i in 0..REQUESTS_PER_THREAD {
                    // Mixed patterns: each thread walks all three circuits.
                    let (_, base) = &patterns[(t + i) % patterns.len()];
                    let m = restamp_columns(base, &mut rng);
                    let n = m.nrows();
                    let rhs: Vec<Vec<f64>> = (0..RHS_PER_REQUEST)
                        .map(|s| (0..n).map(|j| ((j + s + i) % 11) as f64 - 5.0).collect())
                        .collect();
                    let xs = pool.solve_many(&m, &rhs).expect("solve");
                    for (x, b) in xs.iter().zip(&rhs) {
                        assert!(residual(&m, x, b) < 1e-6);
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();

    let st = pool.stats();
    let threaded_requests = THREADS * REQUESTS_PER_THREAD;
    let threaded_solves = threaded_requests * RHS_PER_REQUEST;
    println!(
        "\nserved {threaded_requests} requests ({threaded_solves} RHS) from {THREADS} threads \
         in {:.1} ms ({:.0} solves/s)",
        wall * 1e3,
        threaded_solves as f64 / wall
    );
    println!(
        "symbolic-cache hit rate: {:.1}%  (hits {}, misses {}; {} full factorizations, {} refactorizations)",
        st.hit_rate() * 100.0,
        st.hits,
        st.misses,
        st.factors,
        st.refactors
    );
    println!(
        "solve latency: p50 {:.2} ms, p99 {:.2} ms (mean {:.2} ms over {} requests)",
        st.p50_ms(),
        st.p99_ms(),
        st.latency.mean_ms(),
        st.latency.count()
    );

    println!("\nper-pattern amortization (symbolic pipeline ran once each):");
    for (key, stats) in pool.entry_stats() {
        let ap = amortization_profile(&stats);
        println!(
            "  n={:6} nnz={:8}  symbolic x{}  numeric x{:3}  reuse {:5.1}x  cpu saved {:8.1} ms",
            key.n,
            key.nnz,
            ap.symbolic_runs,
            ap.numeric_runs,
            ap.reuse(),
            ap.cpu_ms_saved()
        );
    }

    assert!(
        st.hit_rate() >= 0.9,
        "repeated-pattern workload must hit the symbolic cache >= 90%"
    );
    println!("\nhit-rate acceptance (>= 90%): OK");
    Ok(())
}
