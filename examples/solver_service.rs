//! The L3 coordinator as a service: load several factored systems, serve
//! concurrent solve/refactor requests from client threads, report
//! latency/throughput — the "serving" view of the solver (vLLM-router
//! flavor, scaled to a linear-algebra service).
//!
//! ```text
//! cargo run --release --example solver_service
//! ```

use std::time::Instant;

use glu3::coordinator::SolverService;
use glu3::glu::GluOptions;
use glu3::numeric::residual;
use glu3::sparse::gen::{self, SuiteMatrix};

fn main() -> anyhow::Result<()> {
    let mut svc = SolverService::new();

    // Load three systems (each factored on its own worker thread).
    for m in [
        SuiteMatrix::Rajat12,
        SuiteMatrix::Circuit2,
        SuiteMatrix::Memplus,
    ] {
        let t0 = Instant::now();
        let a = gen::generate(&m.spec());
        svc.load(m.ufl_name(), a, GluOptions::default())?;
        println!(
            "loaded {:10} in {:6.1} ms",
            m.ufl_name(),
            t0.elapsed().as_secs_f64() * 1e3
        );
    }

    // Serve a burst of solve requests against each system from client
    // threads; the worker batches RHS sharing the same factors.
    let t0 = Instant::now();
    let mut total = 0usize;
    std::thread::scope(|scope| {
        for m in [
            SuiteMatrix::Rajat12,
            SuiteMatrix::Circuit2,
            SuiteMatrix::Memplus,
        ] {
            let svc = &svc;
            scope.spawn(move || {
                let a = gen::generate(&m.spec());
                let n = a.nrows();
                let h = svc.get(m.ufl_name()).expect("loaded");
                let batch: Vec<Vec<f64>> = (0..8)
                    .map(|s| (0..n).map(|i| ((i + s) % 11) as f64 - 5.0).collect())
                    .collect();
                let xs = h.solve_batch(batch.clone()).expect("solve");
                for (x, b) in xs.iter().zip(&batch) {
                    assert!(residual(&a, x, b) < 1e-7);
                }
            });
        }
        total += 3 * 8;
    });
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "served {total} solves across 3 systems in {:.1} ms ({:.0} solves/s)",
        dt * 1e3,
        total as f64 / dt
    );

    // Refactor one system in place (values-only update) and solve again.
    let m = SuiteMatrix::Circuit2;
    let mut a2 = gen::generate(&m.spec());
    for v in a2.values_mut() {
        *v *= 2.0;
    }
    let h = svc.get(m.ufl_name()).unwrap();
    let t0 = Instant::now();
    h.refactor(a2.clone())?;
    println!(
        "refactor {} in {:.2} ms (symbolic reused on the worker)",
        m.ufl_name(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    let b = vec![1.0; a2.nrows()];
    let x = h.solve(b.clone())?;
    println!("post-refactor residual: {:.3e}", residual(&a2, &x, &b));
    Ok(())
}
