//! The fault-tolerant serving core under deterministic chaos: a
//! multi-tenant [`Server`] over the [`SolverPool`](glu3::coordinator::SolverPool)
//! absorbing injected delays, robustness-ladder repairs and escalations,
//! singular exhaustions, poisoned checkouts, and submission bursts —
//! while losing **zero** requests.
//!
//! Four tenants (priorities 0–3) submit mixed-pattern, multi-RHS work
//! against three circuit matrices. A seeded [`FaultPlan`] (≥10% fault
//! rate) decides per request id — deterministically, independent of
//! thread timing — what goes wrong. The demo then asserts the serving
//! invariants:
//!
//! - **zero lost or hung requests**: every admitted request resolves
//!   with a solution or a *typed* error ([`GluError`] downcast);
//! - **bounded tail**: p999 latency stays under the deadline;
//! - **amortization survives chaos**: the symbolic pipeline runs far
//!   fewer times than the request count (caching + coalescing).
//!
//! ```text
//! cargo run --release --example solver_service
//! ```

use std::time::Duration;

use glu3::coordinator::{FaultPlan, ServeConfig, Server, Ticket};
use glu3::glu::GluOptions;
use glu3::numeric::GluError;
use glu3::sparse::gen::{self, restamp_columns, SuiteMatrix};
use glu3::sparse::Csc;
use glu3::util::Rng;

const TENANTS: usize = 4;
const REQUESTS: usize = 120;
const RHS_PER_REQUEST: usize = 3;
const DEADLINE_MS: u64 = 5_000;
const FAULT_SEED: u64 = 0xC11A05;

fn main() -> anyhow::Result<()> {
    // Three distinct sparsity patterns (three circuits being simulated).
    let patterns: Vec<(&str, Csc)> = [
        SuiteMatrix::Rajat12,
        SuiteMatrix::Circuit2,
        SuiteMatrix::Memplus,
    ]
    .into_iter()
    .map(|m| (m.ufl_name(), gen::generate(&m.spec())))
    .collect();
    for (name, a) in &patterns {
        println!("pattern {:10} n={:6} nz={}", name, a.nrows(), a.nnz());
    }

    let plan = FaultPlan::chaos(FAULT_SEED);
    println!(
        "fault plan: seed {:#x}, {:.0}% injected faults (+{:.0}% bursts)\n",
        plan.seed,
        plan.fault_rate() * 100.0,
        plan.burst * 100.0
    );
    let cfg = ServeConfig {
        queue_capacity: 48,
        workers: 2,
        default_deadline: Duration::from_millis(DEADLINE_MS),
        fault_plan: plan.clone(),
        ..ServeConfig::default()
    };
    let server = Server::new(GluOptions::default(), cfg);
    let tenants: Vec<_> = (0..TENANTS)
        .map(|i| server.tenant(&format!("tenant-{i}"), i as u8))
        .collect();

    // Warm each pattern so injected singular stamps always land on cached
    // symbolic state (the retention scenario), then submit the storm.
    for (_, a) in &patterns {
        server.warm(a)?;
    }
    let mut rng = Rng::new(FAULT_SEED);
    let mut tickets: Vec<Ticket> = Vec::with_capacity(REQUESTS);
    let mut admitted = 0u64;
    let mut turned_away = 0u64;
    for i in 0..REQUESTS {
        let (_, base) = &patterns[i % patterns.len()];
        let m = restamp_columns(base, &mut rng);
        let rhs = vec![vec![1.0; m.nrows()]; RHS_PER_REQUEST];
        match server.submit(tenants[i % TENANTS], m.clone(), rhs.clone()) {
            Ok(t) => {
                // Deterministic bursts: duplicate this exact stamp so the
                // queue sees same-values spikes for coalescing to absorb.
                if plan.burst_at(t.id()) {
                    match server.submit(tenants[(i + 1) % TENANTS], m, rhs) {
                        Ok(t2) => tickets.push(t2),
                        Err(_) => turned_away += 1,
                    }
                }
                tickets.push(t);
                admitted += 1;
            }
            // Back-pressure is an answer, not a loss: typed Overloaded.
            Err(e) => {
                assert!(
                    e.downcast_ref::<GluError>().is_some(),
                    "admission errors must be typed: {e:#}"
                );
                turned_away += 1;
            }
        }
    }

    // Every ticket must resolve — solution or *typed* error, never a hang.
    let mut ok = 0u64;
    let mut typed_errors = 0u64;
    for t in tickets {
        match t.wait() {
            Ok(xs) => {
                assert_eq!(xs.len(), RHS_PER_REQUEST);
                ok += 1;
            }
            Err(e) => {
                let typed = e.downcast_ref::<GluError>();
                assert!(typed.is_some(), "untyped service error: {e:#}");
                typed_errors += 1;
            }
        }
    }

    let st = server.shutdown();
    println!(
        "admitted {admitted} (+bursts), turned away {turned_away}; \
         resolved {ok} ok + {typed_errors} typed errors"
    );
    println!(
        "counters: completed {}, deadline missed {}, failed {}, retries {}, \
         coalesced {}, degraded checkouts {}",
        st.completed, st.deadline_missed, st.failed, st.retries, st.coalesced,
        st.degraded_checkouts
    );
    println!(
        "injected: {} delays, {} repairs, {} escalations, {} singulars, {} poisons",
        st.injected_delays,
        st.injected_repairs,
        st.injected_escalations,
        st.injected_singulars,
        st.injected_poisons
    );
    println!(
        "latency: p50 {:.2} ms, p99 {:.2} ms, p999 {:.2} ms; queue depth max {} / cap {}",
        st.p50_ms(),
        st.p99_ms(),
        st.p999_ms(),
        st.depth.max_depth(),
        st.queue_capacity
    );
    println!(
        "amortization: {} symbolic runs vs {} submitted requests",
        st.symbolic_runs, st.submitted
    );

    // The serving invariants this demo exists to prove.
    assert_eq!(st.in_flight(), 0, "zero lost/hung requests");
    assert!(st.injected_faults() > 0, "the chaos plan must actually fire");
    assert!(
        st.p999_ms() < DEADLINE_MS as f64,
        "tail latency must stay inside the deadline"
    );
    assert!(
        st.symbolic_runs < st.submitted as usize,
        "caching must beat one-symbolic-per-request even under chaos"
    );
    println!("\nchaos acceptance (zero lost, typed errors, bounded tail): OK");
    Ok(())
}
