//! Parallelism anatomy of a factorization (the Fig. 10 analysis as a
//! reusable tool): prints the per-level column/subcolumn profile, the
//! kernel mode each level gets, and what-if timings under each policy.
//!
//! ```text
//! cargo run --release --example parallelism_profile [suite-name]
//! ```

use glu3::glu::profile::{parallelism_profile, size_subcol_correlation};
use glu3::glu::{GluOptions, GluSolver};
use glu3::gpusim::{DeviceConfig, Policy};
use glu3::sparse::gen::{self, SuiteMatrix};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "rajat27".into());
    let m = SuiteMatrix::ALL
        .iter()
        .find(|m| m.ufl_name().eq_ignore_ascii_case(&name))
        .copied()
        .ok_or_else(|| anyhow::anyhow!("unknown suite matrix {name}"))?;
    let a = gen::generate(&m.spec());
    let solver = GluSolver::factor(&a, &GluOptions::default())?;
    let prof = parallelism_profile(solver.symbolic(), solver.levels());
    let dev = DeviceConfig::titan_x();

    println!("# {} — {} levels", m.ufl_name(), prof.len());
    println!("{:>6} {:>8} {:>12} {:>10} {:>6}", "level", "size", "max_subcols", "mean_Llen", "mode");
    let stride = (prof.len() / 40).max(1);
    for (i, p) in prof.iter().enumerate() {
        if i > 10 && i % stride != 0 && i + 1 != prof.len() {
            continue;
        }
        let mode = glu3::gpusim::exec::select_mode(p.size, 16, &dev);
        println!(
            "{:>6} {:>8} {:>12} {:>10.1} {:>6}",
            p.level,
            p.size,
            p.max_subcols,
            p.mean_l_len,
            mode.label()
        );
    }
    println!(
        "size/max-subcol correlation: {:.3} (paper: inversely correlated)",
        size_subcol_correlation(&prof)
    );

    println!("\nwhat-if kernel timings on this schedule:");
    for policy in [
        Policy::glu3(),
        Policy::glu3_no_small(),
        Policy::glu3_no_stream(),
        Policy::glu2_fixed(),
        Policy::lee_enhanced(),
    ] {
        let opts = GluOptions {
            policy: policy.clone(),
            ..Default::default()
        };
        let s = GluSolver::factor(&a, &opts)?;
        println!("  {:24} {:>10.3} ms", policy.name, s.stats().numeric_ms);
    }
    Ok(())
}
