//! Quickstart: factor a circuit matrix with GLU3.0, solve, and inspect the
//! pipeline statistics — the 20-line tour of the public API.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use glu3::glu::{GluOptions, GluSolver};
use glu3::numeric::residual;
use glu3::sparse::gen::{self, SuiteMatrix};

fn main() -> anyhow::Result<()> {
    // 1. A circuit matrix (the synthetic stand-in for UFL's circuit_2).
    let a = gen::generate(&SuiteMatrix::Circuit2.spec());
    println!("matrix: {} rows, {} nonzeros", a.nrows(), a.nnz());

    // 2. Factor: MC64-style matching + AMD + symbolic fill + relaxed
    //    dependency detection (Algorithm 4) + the adaptive 3-mode kernel on
    //    the simulated TITAN X.
    let mut solver = GluSolver::factor(&a, &GluOptions::default())?;
    let st = solver.stats();
    println!(
        "factored: nnz {} (fill {:.2}x), {} levels, CPU {:.1} ms, kernel {:.3} ms",
        st.nnz,
        st.nnz as f64 / st.nz as f64,
        st.num_levels,
        st.cpu_ms(),
        st.numeric_ms
    );
    if let Some(sim) = &st.sim {
        let (a_, b_, c_) = sim.level_distribution();
        println!("level types: A={a_} B={b_} C={c_} (paper Fig. 10 taxonomy)");
    }

    // 3. Solve and verify.
    let b = vec![1.0; a.nrows()];
    let x = solver.solve(&b)?;
    println!("solve: relative residual {:.3e}", residual(&a, &x, &b));

    // 4. Refactor with new values on the same pattern (the Newton-Raphson
    //    pattern): symbolic state is reused, only the numeric kernel reruns.
    let mut a2 = a.clone();
    for v in a2.values_mut() {
        *v *= 1.1;
    }
    solver.refactor(&a2)?;
    let x2 = solver.solve(&b)?;
    println!(
        "refactor + solve: relative residual {:.3e}",
        residual(&a2, &x2, &b)
    );
    Ok(())
}
