//! End-to-end driver (DESIGN.md §4): SPICE-lite transient simulation of a
//! nonlinear power-grid circuit, with every linear solve going through the
//! GLU3.0 pipeline — DC operating point by Newton–Raphson, then a
//! backward-Euler transient where each step refactors the same Jacobian
//! pattern. Reports the paper's headline metric for this workload: numeric
//! refactorization time with symbolic reuse vs. the cost of redoing the
//! full pipeline every iteration.
//!
//! ```text
//! cargo run --release --example circuit_sim [grid_side] [steps]
//! ```

use glu3::circuit::netlist::diode_grid;
use glu3::circuit::{transient, MnaSystem, TranOptions};
use glu3::coordinator::nr::{newton_raphson, NonlinearSystem, NrOptions};
use glu3::glu::{GluOptions, GluSolver};

fn main() -> anyhow::Result<()> {
    let side: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(24);
    let steps: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(50);

    // A side x side power grid with diode clamps: ~side^2 nodes.
    let nl = diode_grid(side, side, 1.8, side, 42);
    println!(
        "circuit: {} nodes, {} elements",
        nl.n_nodes(),
        nl.elements.len()
    );

    // --- DC operating point (Newton-Raphson over GLU3.0). ---
    let sys = MnaSystem::dc(nl.clone());
    let dc = newton_raphson(
        &sys,
        &vec![0.0; sys.dim()],
        &NrOptions {
            max_iters: 200,
            damping: 0.7,
            ..Default::default()
        },
    )?;
    anyhow::ensure!(dc.converged, "DC failed to converge");
    println!(
        "DC converged in {} NR iterations; |F| trajectory: {:?}",
        dc.iterations,
        &dc.residual_norms[..dc.residual_norms.len().min(6)]
    );

    // --- Transient (backward Euler): power-on from discharged decaps, so
    // every step does real Newton work toward the DC operating point. ---
    let res = transient(
        &nl,
        &vec![0.0; sys.dim()],
        &TranOptions {
            dt: 2e-9,
            steps,
            nr_max_iters: 200,
            ..Default::default()
        },
    )?;
    let v00 = nl.node("g0_0").unwrap() - 1;
    let trace = res.trace(v00);
    println!(
        "transient: {} steps, {} NR iterations, {} refactorizations",
        steps, res.nr_iterations, res.refactorizations
    );
    println!(
        "v(g0_0): t0 {:.4} V -> tEnd {:.4} V",
        trace[0],
        trace.last().unwrap()
    );

    // --- The headline metric: refactor-with-symbolic-reuse vs full-factor. ---
    let j = sys.jacobian(&dc.x);
    let mut solver = GluSolver::factor(&j, &GluOptions::default())?;
    let full_ms = solver.stats().cpu_ms() + solver.stats().numeric_ms;
    solver.refactor(&j)?;
    let re_ms = solver.stats().numeric_ms;
    println!(
        "one factor: {:.2} ms (CPU preprocess+symbolic {:.2} + kernel {:.3})",
        full_ms,
        solver.stats().cpu_ms(),
        solver.stats().numeric_ms
    );
    println!(
        "refactor (symbolic reused): {:.3} ms kernel only -> {:.1}x cheaper per NR iteration",
        re_ms,
        full_ms / re_ms.max(1e-9)
    );
    println!(
        "whole transient spent {:.2} ms in numeric kernels + {:.2} ms one-time CPU analysis",
        res.numeric_ms_total, res.cpu_ms_once
    );
    Ok(())
}
